"""The HTTP serving tier: coalescing, QoS shedding, signed delivery,
manifest caching, and the /metrics + /healthz surface.

Each test runs a real `VSSService` on an ephemeral port and speaks
stdlib HTTP at it — the same wire a VDBMS client would use."""
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import codec
from repro.obs.registry import MetricsRegistry
from repro.serving.qos import (
    REASON_QUEUE_DEPTH,
    REASON_TENANT_RATE,
    AdmissionController,
    TokenBucket,
)
from repro.serving.service import VSSService, spec_from_json
from repro.serving.signing import UrlSigner


def _post(base, body, tenant="t0"):
    req = urllib.request.Request(
        base + "/v1/read", data=json.dumps(body).encode(),
        headers={"X-VSS-Tenant": tenant,
                 "Content-Type": "application/json"},
        method="POST",
    )
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}"), dict(e.headers)


def _get(base, path):
    try:
        with urllib.request.urlopen(base + path) as r:
            return r.status, r.read(), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, e.read(), dict(e.headers)


@pytest.fixture()
def served(vss, clip):
    vss.write("road", clip, fps=30.0, codec="tvc-med", gop_frames=15)
    service = VSSService(vss, window_s=0.01)
    yield service, vss
    service.close()


def _fetch_frames(base, manifest):
    segs = []
    for seg in manifest["segments"]:
        status, data, _ = _get(base, seg["url"])
        assert status == 200
        assert len(data) == seg["nbytes"]
        segs.append(data)
    return np.concatenate(
        [codec.decode_gop(codec.deserialize_gop(b)) for b in segs], axis=0
    )


# ---------------------------------------------------------------------------
# control plane + data plane
# ---------------------------------------------------------------------------

def test_read_manifest_and_bit_exact_segments(served):
    service, vss = served
    status, manifest, _ = _post(
        service.url, {"name": "road", "t": [0.0, 1.0], "codec": "tvc-med"}
    )
    assert status == 200
    assert manifest["codec"] == "tvc-med"
    assert manifest["segments"], "manifest must carry segment URLs"
    got = _fetch_frames(service.url, manifest)
    ref = vss.read("road", t=(0.0, 1.0), codec="tvc-med").frames
    assert np.array_equal(got, ref)


def test_rgb_read_serves_segments(served):
    service, vss = served
    status, manifest, _ = _post(
        service.url, {"name": "road", "t": [0.0, 0.5], "codec": "rgb"}
    )
    assert status == 200
    got = _fetch_frames(service.url, manifest)
    assert np.array_equal(
        got, vss.read("road", t=(0.0, 0.5), codec="rgb").frames
    )


def test_concurrent_requests_coalesce_into_fewer_batches(vss, clip):
    vss.write("road", clip, fps=30.0, codec="tvc-med", gop_frames=15)
    reg = MetricsRegistry()
    service = VSSService(vss, window_s=0.25, registry=reg)
    try:
        n = 8
        results = [None] * n

        def worker(i):
            results[i] = _post(
                service.url,
                {"name": "road", "t": [0.0, 1.0], "codec": "tvc-med"},
            )

        threads = [
            threading.Thread(target=worker, args=(i,)) for i in range(n)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert all(r is not None and r[0] == 200 for r in results)
        batches = reg.value("vss_serve_batches_total")
        assert batches < n, f"no coalescing: {batches} batches for {n} reqs"
        # identical concurrent requests: every client got the same bytes
        first = _fetch_frames(service.url, results[0][1])
        ref = vss.read("road", t=(0.0, 1.0), codec="tvc-med").frames
        assert np.array_equal(first, ref)
    finally:
        service.close()


def test_bad_spec_400_unknown_video_404(served):
    service, _vss = served
    assert _post(service.url, {"name": "road", "t": [5, 1]})[0] == 400
    assert _post(service.url, {"name": "road", "bogus": 1})[0] == 400
    assert _post(service.url, {"name": "ghost"})[0] == 404
    assert _post(service.url, [1, 2, 3])[0] == 400


def test_one_bad_spec_does_not_poison_coalesced_batchmates(vss, clip):
    """An invalid-at-execution spec in a coalesced batch fails alone;
    its batchmates still answer 200 via per-request fallback."""
    vss.write("road", clip, fps=30.0, codec="tvc-med", gop_frames=15)
    reg = MetricsRegistry()
    service = VSSService(vss, window_s=0.25, registry=reg)
    try:
        bodies = [
            {"name": "road", "t": [0.0, 1.0], "codec": "tvc-med"},
            # resolves past the stored interval -> ValueError at resolve
            {"name": "road", "t": [0.0, 10_000.0], "codec": "tvc-med"},
            {"name": "road", "t": [1.0, 2.0], "codec": "tvc-med"},
        ]
        results = [None] * len(bodies)

        def worker(i):
            results[i] = _post(service.url, bodies[i])

        threads = [
            threading.Thread(target=worker, args=(i,))
            for i in range(len(bodies))
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        codes = [r[0] for r in results]
        assert codes[0] == 200 and codes[2] == 200
        assert codes[1] == 400
    finally:
        service.close()


# ---------------------------------------------------------------------------
# QoS: shedding + deadlines
# ---------------------------------------------------------------------------

def test_tenant_rate_shed_with_retry_after(vss, clip):
    vss.write("road", clip, fps=30.0, codec="tvc-med", gop_frames=15)
    reg = MetricsRegistry()
    service = VSSService(
        vss,
        admission=AdmissionController(
            tenant_rate=0.5, tenant_burst=2.0, registry=reg
        ),
        registry=reg,
    )
    try:
        body = {"name": "road", "t": [0.0, 0.5], "codec": "tvc-med"}
        codes = [_post(service.url, body, tenant="greedy") for _ in range(4)]
        assert [c[0] for c in codes[:2]] == [200, 200]
        shed = codes[2]
        assert shed[0] == 503
        assert shed[2]["X-VSS-Shed-Reason"] == REASON_TENANT_RATE
        assert int(shed[2]["Retry-After"]) >= 1
        # another tenant's budget is untouched
        assert _post(service.url, body, tenant="polite")[0] == 200
        assert reg.value(
            "vss_serve_shed_total", {"reason": REASON_TENANT_RATE}
        ) >= 1
    finally:
        service.close()


def test_past_deadline_request_is_shed(served):
    service, _vss = served
    status, body, headers = _post(
        service.url,
        {"name": "road", "t": [0.0, 0.5], "codec": "tvc-med",
         "deadline_ms": 0},
    )
    assert status == 503
    assert headers["X-VSS-Shed-Reason"] == "deadline"
    assert body["reason"] == "deadline"
    # a generous deadline sails through
    assert _post(
        service.url,
        {"name": "road", "t": [0.0, 0.5], "codec": "tvc-med",
         "deadline_ms": 60_000},
    )[0] == 200


def test_admission_controller_queue_and_bytes_limits():
    reg = MetricsRegistry()
    ac = AdmissionController(
        queue_limit=2, inflight_bytes_limit=100, tenant_rate=1000.0,
        tenant_burst=1000.0, registry=reg,
    )
    assert ac.admit() is None
    assert ac.admit() is None
    denial = ac.admit()
    assert denial is not None and denial.reason == REASON_QUEUE_DEPTH
    ac.release()
    assert ac.admit() is None
    ac.release()
    ac.release()
    ac.hold_bytes(150)
    denial = ac.admit()
    assert denial is not None and denial.reason == "inflight-bytes"
    ac.drop_bytes(150)
    assert ac.admit() is None
    assert reg.value("vss_serve_queue_depth") == ac.in_flight


def test_token_bucket_refills():
    tb = TokenBucket(rate=100.0, burst=2.0)
    assert tb.try_acquire() is None
    assert tb.try_acquire() is None
    retry = tb.try_acquire()
    assert retry is not None and retry > 0
    time.sleep(retry + 0.05)
    assert tb.try_acquire() is None


# ---------------------------------------------------------------------------
# signed URLs
# ---------------------------------------------------------------------------

def test_signer_verify_reasons():
    s = UrlSigner(secret=b"k", ttl_s=10.0)
    url = s.sign("/v1/segment/abc/0", now=1000.0)
    path, _, query = url.partition("?")
    q = dict(p.split("=") for p in query.split("&"))
    assert s.verify(path, q["exp"], q["sig"], now=1005.0) is None
    assert s.verify(path, q["exp"], q["sig"], now=1011.0) == "expired"
    assert s.verify(path, q["exp"], "0" * 64, now=1005.0) == "bad-signature"
    assert s.verify(path, "soon", q["sig"]) == "bad-exp"
    assert s.verify("/v1/segment/abc/1", q["exp"], q["sig"],
                    now=1005.0) == "bad-signature"


def test_tampered_and_expired_segment_urls_rejected(vss, clip):
    vss.write("road", clip, fps=30.0, codec="tvc-med", gop_frames=15)
    service = VSSService(vss)
    try:
        status, manifest, _ = _post(
            service.url,
            {"name": "road", "t": [0.0, 0.5], "codec": "tvc-med"},
        )
        assert status == 200
        url = manifest["segments"][0]["url"]
        assert _get(service.url, url)[0] == 200
        # tampered signature
        assert _get(service.url, url.replace("sig=", "sig=0"))[0] == 403
        # no signature at all
        assert _get(service.url, url.partition("?")[0])[0] == 403
        # a token whose (validly signed) expiry already passed
        path = url.partition("?")[0]
        stale = service.signer.sign(
            path, now=time.time() - service.signer.ttl_s - 60
        )
        assert _get(service.url, stale)[0] == 410
    finally:
        service.close()


# ---------------------------------------------------------------------------
# stored manifests + cache invalidation
# ---------------------------------------------------------------------------

def test_manifest_lists_gops_and_serves_signed_objects(served):
    service, vss = served
    status, body, _ = _get(service.url, "/v1/manifest/road")
    manifest = json.loads(body)
    assert status == 200
    assert manifest["name"] == "road"
    assert manifest["total_bytes"] > 0
    gops = [g for p in manifest["physicals"] for g in p["gops"]]
    assert gops
    status, data, _ = _get(service.url, gops[0]["url"])
    assert status == 200
    enc = codec.deserialize_gop(data)
    assert enc.nbytes == gops[0]["nbytes"] or len(data) > 0
    # unknown name
    assert _get(service.url, "/v1/manifest/ghost")[0] == 404


def test_manifest_cache_hit_then_write_invalidates(vss, clip):
    vss.write("road", clip, fps=30.0, codec="tvc-med", gop_frames=15)
    reg = MetricsRegistry()
    service = VSSService(vss, registry=reg)
    try:
        assert _get(service.url, "/v1/manifest/road")[0] == 200
        assert _get(service.url, "/v1/manifest/road")[0] == 200
        assert reg.value("vss_serve_manifest_cache_misses_total") == 1
        assert reg.value("vss_serve_manifest_cache_hits_total") == 1
        # a write to a DIFFERENT video leaves the entry alone
        vss.write("other", clip[:15], fps=30.0, codec="rgb")
        assert _get(service.url, "/v1/manifest/road")[0] == 200
        assert reg.value("vss_serve_manifest_cache_hits_total") == 2
        # dropping the video invalidates its entry and 404s afterwards
        vss.drop("road")
        assert reg.value("vss_serve_manifest_invalidations_total") >= 1
        assert _get(service.url, "/v1/manifest/road")[0] == 404
    finally:
        service.close()


def test_manifest_reflects_appends_after_invalidation(vss, clip):
    vss.write("road", clip[:30], fps=30.0, codec="tvc-med", gop_frames=15)
    service = VSSService(vss)
    try:
        first = json.loads(_get(service.url, "/v1/manifest/road")[1])
        n_before = sum(
            len(p["gops"]) for p in first["physicals"]
        )
        # stream more frames in: the writer close invalidates the entry
        vss.drop("road")
        vss.write("road", clip, fps=30.0, codec="tvc-med", gop_frames=15)
        second = json.loads(_get(service.url, "/v1/manifest/road")[1])
        n_after = sum(len(p["gops"]) for p in second["physicals"])
        assert n_after > n_before
    finally:
        service.close()


# ---------------------------------------------------------------------------
# observability surface
# ---------------------------------------------------------------------------

def test_metrics_healthz_and_videos(served):
    service, _vss = served
    assert _post(
        service.url, {"name": "road", "t": [0.0, 0.5], "codec": "tvc-med"}
    )[0] == 200
    status, body, headers = _get(service.url, "/metrics")
    assert status == 200
    assert headers["Content-Type"].startswith("text/plain")
    text = body.decode()
    for family in (
        "vss_serve_requests_total",
        "vss_serve_admitted_total",
        "vss_serve_batches_total",
        "vss_serve_coalesce_width",
        "vss_serve_ttfb_seconds",
        "vss_serve_e2e_seconds",
        "vss_serve_queue_depth",
        "vss_serve_tenant_tokens",
    ):
        assert family in text, f"missing metric family {family}"
    status, body, _ = _get(service.url, "/healthz")
    report = json.loads(body)
    assert status == 200 and report["status"] == "ok"
    assert report["serving"]["coalescer_alive"] is True
    status, body, _ = _get(service.url, "/v1/videos")
    assert status == 200 and json.loads(body) == ["road"]


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------

def test_spec_from_json():
    spec = spec_from_json({
        "name": "v", "t": [0, 2], "codec": "hevc", "priority": 3,
        "deadline_ms": 50,
    })
    assert spec.name == "v" and spec.t == (0.0, 2.0)
    assert spec.codec == "tvc-hi" and spec.priority == 3
    assert spec.deadline_ms == 50.0
    with pytest.raises(ValueError):
        spec_from_json({"name": "v", "unknown_knob": 1})
    with pytest.raises(ValueError):
        spec_from_json({})
    with pytest.raises(ValueError):
        spec_from_json("just a string")


def _get_range(base, path, rng):
    req = urllib.request.Request(base + path, headers={"Range": rng})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, r.read(), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, e.read(), dict(e.headers)


def test_signed_gop_fetch_honours_range(served):
    """A signed /v1/gop URL answers HTTP Range requests with 206 +
    Content-Range (416 when unsatisfiable) so sub-GOP clients can pull
    just the byte prefix their frame trim decodes."""
    service, vss = served
    status, body, _ = _get(service.url, "/v1/manifest/road")
    gops = [g for p in json.loads(body)["physicals"] for g in p["gops"]]
    url = gops[0]["url"]
    status, full, headers = _get(service.url, url)
    assert status == 200
    assert headers.get("Accept-Ranges") == "bytes"

    status, part, headers = _get_range(service.url, url, "bytes=0-99")
    assert status == 206
    assert part == full[:100]
    assert headers["Content-Range"] == f"bytes 0-99/{len(full)}"

    status, tail, headers = _get_range(service.url, url, "bytes=100-")
    assert status == 206
    assert tail == full[100:]

    status, _body, headers = _get_range(
        service.url, url, f"bytes={len(full)}-"
    )
    assert status == 416
    assert headers["Content-Range"] == f"bytes */{len(full)}"


def test_segment_fetch_honours_range(served):
    service, vss = served
    status, manifest, _ = _post(
        service.url, {"name": "road", "t": [0.0, 0.5], "codec": "tvc-med"}
    )
    assert status == 200
    seg = manifest["segments"][0]
    _status, full, _ = _get(service.url, seg["url"])
    status, part, headers = _get_range(service.url, seg["url"], "bytes=8-23")
    assert status == 206
    assert part == full[8:24]
    assert headers["Content-Range"] == f"bytes 8-23/{len(full)}"
