"""`VSSConfig` / `ServiceConfig`: the unified construction surface.

Covers the three entry points (Python, ``VSS_*`` environment
overrides, strict JSON), the deprecated-keyword shim on both `VSS` and
`VSSService`, and the single-file service boot.
"""
import inspect

import numpy as np
import pytest

from repro.core.cache import CachePolicy
from repro.core.config import (
    AdaptiveConfig,
    DeferredConfig,
    IngestConfig,
    LEGACY_KWARGS,
    VSSConfig,
    config_from_legacy,
    parse_bool,
    strict_keys,
)
from repro.core.store import VSS
from repro.obs import MetricsRegistry
from repro.serving.config import ServiceConfig, boot_from_json
from repro.serving.service import VSSService


# ---------------------------------------------------------------------------
# legacy keyword shim
# ---------------------------------------------------------------------------

def test_every_legacy_kwarg_maps_into_config():
    cost_model, registry = object(), object()
    values = {
        "backend": "memory",
        "budget_multiple": 3.5,
        "solver": "greedy",
        "cost_model": cost_model,
        "cache_policy": CachePolicy(gamma=9.0),
        "enable_deferred": False,
        "enable_compaction": False,
        "use_pallas": True,
        "pipelined_ingest": False,
        "ingest_workers": 7,
        "ingest_queue_gops": 9,
        "registry": registry,
        "trace_capacity": 77,
    }
    assert set(values) == set(LEGACY_KWARGS)
    cfg = config_from_legacy(None, values)
    for kwarg, path in LEGACY_KWARGS.items():
        node = cfg
        for part in path.split("."):
            node = getattr(node, part)
        assert node == values[kwarg], kwarg
    # the shim signature itself covers every documented legacy kwarg
    params = set(inspect.signature(VSS.__init__).parameters)
    assert set(LEGACY_KWARGS) <= params


def test_legacy_none_means_default():
    cfg = config_from_legacy(None, {"cache_policy": None, "cost_model": None})
    assert cfg == VSSConfig()


def test_legacy_kwargs_warn_and_match_config_store(tmp_path, clip):
    with pytest.warns(DeprecationWarning, match="deprecated"):
        old = VSS(
            str(tmp_path / "old"), budget_multiple=5.0,
            enable_deferred=False, enable_compaction=False,
            ingest_workers=3, ingest_queue_gops=8, trace_capacity=64,
        )
    new = VSS(str(tmp_path / "new"), config=VSSConfig(
        budget_multiple=5.0,
        deferred=DeferredConfig(enabled=False),
        compaction=False,
        ingest=IngestConfig(workers=3, queue_gops=8),
        trace_capacity=64,
    ))
    try:
        assert old.config == new.config
        for s in (old, new):
            s.write("v", clip, fps=30.0, codec="tvc-hi")
        a = old.read("v", t=(0.0, 1.0), codec="rgb", cache=False).frames
        b = new.read("v", t=(0.0, 1.0), codec="rgb", cache=False).frames
        assert np.array_equal(a, b)
    finally:
        old.close()
        new.close()


def test_config_constructor_does_not_warn(tmp_path):
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        s = VSS(str(tmp_path / "s"), config=VSSConfig())
    s.close()


# ---------------------------------------------------------------------------
# environment overrides
# ---------------------------------------------------------------------------

def test_with_env_overrides_nested_leaves():
    cfg = VSSConfig().with_env({
        "VSS_SOLVER": "greedy",
        "VSS_BUDGET_MULTIPLE": "4.5",
        "VSS_CACHE_GAMMA": "3.25",
        "VSS_DEFERRED_ENABLED": "off",
        "VSS_ADAPTIVE_ENABLED": "on",
        "VSS_ADAPTIVE_HALF_LIFE_S": "12.5",
        "VSS_INGEST_WORKERS": "7",
        "VSS_USE_PALLAS": "false",
    })
    assert cfg.solver == "greedy"
    assert cfg.budget_multiple == 4.5
    assert cfg.cache.gamma == 3.25
    assert cfg.deferred.enabled is False
    assert cfg.adaptive.enabled is True
    assert cfg.adaptive.half_life_s == 12.5
    assert cfg.ingest.workers == 7
    assert cfg.use_pallas is False


def test_explicit_python_wins_over_env():
    cfg = VSSConfig(
        solver="greedy", ingest=IngestConfig(workers=5),
    ).with_env({
        "VSS_SOLVER": "dp",
        "VSS_INGEST_WORKERS": "9",
        "VSS_INGEST_QUEUE_GOPS": "64",  # still at default: env wins
    })
    assert cfg.solver == "greedy"
    assert cfg.ingest.workers == 5
    assert cfg.ingest.queue_gops == 64


def test_env_invalid_values_raise():
    with pytest.raises(ValueError, match="VSS_ADAPTIVE_ENABLED"):
        VSSConfig().with_env({"VSS_ADAPTIVE_ENABLED": "maybe"})
    with pytest.raises(ValueError, match="VSS_INGEST_WORKERS"):
        VSSConfig().with_env({"VSS_INGEST_WORKERS": "three"})


def test_env_override_reaches_store(tmp_path, monkeypatch):
    monkeypatch.setenv("VSS_ADAPTIVE_ENABLED", "1")
    s = VSS(str(tmp_path / "s"), config=VSSConfig(registry=MetricsRegistry()))
    try:
        assert s.config.adaptive.enabled is True
        assert s.adaptive is not None
    finally:
        s.close()


def test_parse_bool():
    assert parse_bool("YES") is True
    assert parse_bool(" 0 ") is False
    with pytest.raises(ValueError):
        parse_bool("definitely")


# ---------------------------------------------------------------------------
# strict JSON
# ---------------------------------------------------------------------------

def test_from_json_nested_fields():
    cfg = VSSConfig.from_json({
        "backend": "memory",
        "budget_multiple": 4,  # int promotes to float
        "solver": "greedy",
        "use_pallas": False,
        "deferred": {"enabled": False},
        "ingest": {"workers": 3, "autosize": True},
        "adaptive": {"enabled": True, "interval_s": 2},
    })
    assert cfg.backend == "memory"
    assert cfg.budget_multiple == 4.0
    assert cfg.use_pallas is False
    assert cfg.deferred.enabled is False
    assert cfg.ingest == IngestConfig(workers=3, autosize=True)
    assert cfg.adaptive.enabled is True
    assert cfg.adaptive.interval_s == 2.0


@pytest.mark.parametrize("doc", [
    {"nope": 1},                       # unknown top-level field
    {"registry": {}},                  # live objects can't come from JSON
    {"cost_model": {}},
    {"adaptive": {"heat": 1}},         # unknown nested field
    {"ingest": {"workers": "three"}},  # wrong leaf type
    {"use_pallas": "yes"},             # strings are not booleans
    {"compaction": 1},                 # ints are not booleans either
    {"adaptive": 7},                   # nested field must be an object
])
def test_from_json_rejects(doc):
    with pytest.raises(ValueError):
        VSSConfig.from_json(doc)


def test_strict_keys_reports_unknown_and_allowed():
    with pytest.raises(ValueError, match="typo_field"):
        strict_keys({"typo_field": 1}, ("real_field",), "Thing")
    assert strict_keys({"real_field": 1}, ("real_field",), "Thing") == {
        "real_field": 1
    }


# ---------------------------------------------------------------------------
# serving tier: ServiceConfig + single-file boot
# ---------------------------------------------------------------------------

def test_service_config_from_json():
    sc = ServiceConfig.from_json({
        "host": "0.0.0.0", "port": 8123, "window_s": 0.01,
        "admission": {"tenant_rate": 10, "queue_limit": 4},
    })
    assert sc.host == "0.0.0.0"
    assert sc.port == 8123
    assert sc.window_s == 0.01
    assert sc.admission.tenant_rate == 10.0
    assert sc.admission.queue_limit == 4
    with pytest.raises(ValueError):
        ServiceConfig.from_json({"windows": 0.01})
    with pytest.raises(ValueError):
        ServiceConfig.from_json({"admission": {"rate": 1}})


def test_service_legacy_kwargs_warn(tmp_path):
    vss = VSS(str(tmp_path / "s"), config=VSSConfig(
        registry=MetricsRegistry()))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        service = VSSService(vss, window_s=0.01, max_batch=8)
    try:
        assert service.config.window_s == 0.01
        assert service.config.max_batch == 8
    finally:
        service.close()
        vss.close()


def test_boot_from_json(tmp_path):
    vss, service = boot_from_json({
        "root": str(tmp_path / "s"),
        "store": {"adaptive": {"enabled": True}},
        "service": {"port": 0, "window_s": 0.01},
    })
    try:
        assert vss.adaptive is not None
        assert service.config.window_s == 0.01
    finally:
        service.close()
        vss.close()


@pytest.mark.parametrize("doc", [
    {},                                      # root is required
    {"root": 7},                             # ... and must be a string
    {"root": "/tmp/x", "extra": {}},         # unknown top-level section
    {"root": "/tmp/x", "store": {"nope": 1}},
])
def test_boot_from_json_rejects(doc):
    with pytest.raises(ValueError):
        boot_from_json(doc)


def test_adaptive_config_defaults_are_observe_only():
    cfg = AdaptiveConfig()
    assert cfg.profile is True and cfg.enabled is False
