"""LRU_VSS eviction policy (§4)."""

from repro.core.cache import CachePolicy
from repro.core.quality import exact_psnr


def _fill(vss, clip, budget):
    vss.write("v", clip, fps=30.0, codec="tvc-hi", budget_bytes=budget)


def test_baseline_guard_protects_last_lossless_cover(vss, clip):
    _fill(vss, clip, budget=1)  # budget below even the original's size
    evicted = vss.cache.maybe_evict("v")
    # the original is the only ≥τ cover: guard = +∞ on every page
    assert evicted == []
    out = vss.read("v", codec="rgb", cache=False).frames
    assert exact_psnr(out, clip) >= 40.0


def test_eviction_respects_budget_when_possible(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi", budget_bytes=10**9)
    vss.read("v", codec="rgb")  # large raw cached view (≥τ cover too)
    before = vss.catalog.total_bytes("v")
    vss.catalog.set_budget("v", before // 2)
    vss.cache.maybe_evict("v")
    after = vss.catalog.total_bytes("v")
    assert after < before
    out = vss.read("v", codec="rgb", cache=False).frames
    assert exact_psnr(out, clip) >= 40.0  # a lossless cover survived


def test_position_offset_prefers_run_ends(vss, clip):
    """With equal LRU, the policy should evict run ends before middles
    (anti-fragmentation)."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=10,
              budget_bytes=10**9)
    vss.read("v", codec="tvc-med")  # cached view with 2 GOPs... make more
    policy = CachePolicy()
    seqs = policy.sequence_numbers(vss.catalog, "v")
    by_phys = {}
    for p in vss.catalog.physicals_for("v"):
        gops = vss.catalog.gops_for(p.physical_id)
        if len(gops) >= 3 and not p.is_original:
            ends = [seqs[gops[0].gop_id], seqs[gops[-1].gop_id]]
            mids = [seqs[g.gop_id] for g in gops[1:-1]]
            assert min(mids) >= min(ends)
            by_phys[p.physical_id] = True
    # at least one multi-GOP cached view was checked
    # (tvc-med of 60 frames @ default GOP 30 → 2 GOPs; force via raw read)
    vss.read("v", codec="rgb")
    seqs = policy.sequence_numbers(vss.catalog, "v")
    checked = False
    for p in vss.catalog.physicals_for("v"):
        gops = vss.catalog.gops_for(p.physical_id)
        if len(gops) >= 3:
            ends = [seqs[gops[0].gop_id], seqs[gops[-1].gop_id]]
            mids = [s for g in gops[1:-1]
                    if (s := seqs[g.gop_id]) != float("inf")]
            if mids and min(ends) != float("inf"):
                assert min(mids) >= min(ends)
                checked = True
    assert checked


def test_downsampled_view_never_counts_as_cover(vss, clip):
    """Regression: a thumbnail view's (own-resolution) bound is ~0 but it
    must NOT un-guard the full-resolution original — eviction would
    otherwise destroy the only full-detail copy."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi",
              budget_bytes=vss.catalog.total_bytes("v") * 3
              if vss.catalog.logical_exists("v") else None)
    vss.catalog.set_budget("v", vss.catalog.total_bytes("v") + 50_000)
    vss.read("v", resolution=(64, 48), codec="rgb",
             quality_eps_db=20.0)  # big raw thumbnail busts the budget
    # full-resolution read must still be possible at lossless quality
    out = vss.read("v", codec="rgb", cache=False).frames
    assert out.shape == clip.shape
    assert exact_psnr(out, clip) >= 40.0


def test_ordinary_lru_mode(vss, clip):
    """use_vss_offsets=False degrades to plain LRU (the paper's baseline)."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi", budget_bytes=10**9)
    vss.read("v", codec="tvc-med")
    policy = CachePolicy(use_vss_offsets=False)
    seqs = policy.sequence_numbers(vss.catalog, "v")
    finite = [s for s in seqs.values() if s != float("inf")]
    gops = [g for p in vss.catalog.physicals_for("v")
            for g in vss.catalog.gops_for(p.physical_id)]
    by_id = {g.gop_id: g for g in gops}
    for gid, s in seqs.items():
        if s != float("inf"):
            assert s == float(by_id[gid].lru_seq)
