"""`repro.storage`: backend conformance, sharding, tiering, recovery."""
import os
import ssl
import subprocess
import threading

import numpy as np
import pytest

from repro.storage import (
    FaultInjectingBackend,
    LocalFSBackend,
    MemoryBackend,
    ObjectNotFound,
    ObjectServer,
    RangeNotSatisfiable,
    RemoteBackend,
    ReplicatedBackend,
    ShardedBackend,
    TieredBackend,
    make_backend,
    unwrap,
)
from repro.storage.localfs import TEMP_MARKER

# every backend configuration runs the identical conformance suite —
# including the remote client against a live loopback object server
# (plain, and TLS + signed-request auth), and a (quiet) fault wrapper
# proving the chaos shim preserves the contract
BACKEND_SPECS = ("memory", "local", "local:fsync", "sharded2", "sharded4",
                 "tiered", "replicated3", "replicated4r3", "remote",
                 "remotes", "tiered_remote", "fault_wrapped")

_TLS_SECRET = b"conformance-suite-secret"


def mint_tls_cert(dirpath):
    """Self-signed localhost cert via the openssl CLI (no extra deps)."""
    os.makedirs(dirpath, exist_ok=True)
    cert = os.path.join(dirpath, "cert.pem")
    key = os.path.join(dirpath, "key.pem")
    subprocess.run(
        ["openssl", "req", "-x509", "-newkey", "rsa:2048", "-nodes",
         "-keyout", key, "-out", cert, "-days", "1",
         "-subj", "/CN=127.0.0.1",
         "-addext", "subjectAltName=IP:127.0.0.1"],
        check=True, capture_output=True,
    )
    return cert, key


def _make(spec, root):
    if spec == "memory":
        return MemoryBackend()
    if spec == "local":
        return LocalFSBackend(root)
    if spec == "local:fsync":
        return LocalFSBackend(root, fsync=True)
    if spec == "sharded2":
        return ShardedBackend.local(root, 2)
    if spec == "sharded4":
        return ShardedBackend.local(root, 4)
    if spec == "tiered":
        return TieredBackend(LocalFSBackend(root), hot_bytes=1 << 20)
    if spec == "replicated3":
        return ReplicatedBackend.local(root, 3)
    if spec == "replicated4r3":
        return ReplicatedBackend.local(root, 4, replicas=3, write_quorum=2)
    if spec == "remote":
        return RemoteBackend.self_hosted(root, backoff_base=0.01)
    if spec == "remotes":
        # the untrusted-network composition: TLS on the wire + HMAC
        # signed requests, through the `remotes:<url>` spec grammar
        cert, key = mint_tls_cert(root + "-tls")
        ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
        ctx.load_cert_chain(cert, key)
        server = ObjectServer(LocalFSBackend(root), secret=_TLS_SECRET,
                              ssl_context=ctx)
        b = make_backend(f"remotes:{server.url.split('://', 1)[1]}", root,
                         secret=_TLS_SECRET, ca_file=cert)
        unwrap(b, RemoteBackend)._server = server  # close() owns it
        return b
    if spec == "tiered_remote":
        return make_backend("tiered:remote", root)
    if spec == "fault_wrapped":
        return FaultInjectingBackend(MemoryBackend(), seed=0)
    raise AssertionError(spec)


@pytest.fixture(params=BACKEND_SPECS)
def backend(request, tmp_path):
    b = _make(request.param, str(tmp_path / "objects"))
    yield b
    b.close()


# ---------------------------------------------------------------------------
# conformance suite — every backend, same contract (one class, fixture-
# driven; chaos tests in test_faults.py build on the same guarantees)
# ---------------------------------------------------------------------------

class TestBackendConformance:
    def test_put_get_roundtrip(self, backend):
        backend.put("v/1/0.tvc", b"alpha")
        assert backend.get("v/1/0.tvc") == b"alpha"
        backend.put("v/1/0.tvc", b"beta")  # overwrite
        assert backend.get("v/1/0.tvc") == b"beta"

    def test_missing_key_raises(self, backend):
        with pytest.raises(ObjectNotFound):
            backend.get("nope")
        with pytest.raises(ObjectNotFound):
            backend.stat("nope")

    def test_delete_idempotent(self, backend):
        backend.put("k", b"x")
        backend.delete("k")
        backend.delete("k")  # second delete is a no-op
        assert not backend.exists("k")
        backend.delete("never-existed")  # deleting the unknown too

    def test_stat_sizes(self, backend):
        backend.put("a", b"12345")
        assert backend.stat("a").nbytes == 5

    def test_stat_list_consistency(self, backend):
        """list() names exactly the live keys and stat() agrees with
        the stored payload after interleaved puts and deletes."""
        sizes = {f"v/{i}": i + 1 for i in range(8)}
        for k, n in sizes.items():
            backend.put(k, b"z" * n)
        backend.delete("v/3")
        del sizes["v/3"]
        assert sorted(backend.list("v/")) == sorted(sizes)
        for k, n in sizes.items():
            assert backend.stat(k).nbytes == n
            assert len(backend.get(k)) == n

    def test_batch_get_preserves_order(self, backend):
        keys = [f"v/1/{i}.tvc" for i in range(20)]
        for i, k in enumerate(keys):
            backend.put(k, f"payload-{i}".encode())
        got = backend.batch_get(list(reversed(keys)))
        assert got == [f"payload-{i}".encode() for i in reversed(range(20))]

    def test_batch_get_dedupes_repeated_keys(self, backend):
        """A key appearing N times in one batch answers N times, in
        position — the §3 planner dedupes fetches above this seam, so
        repeats must at minimum stay correct below it."""
        backend.put("a", b"A")
        backend.put("b", b"B")
        assert backend.batch_get(["a", "b", "a", "a", "b"]) == [
            b"A", b"B", b"A", b"A", b"B",
        ]

    def test_batch_get_missing_raises(self, backend):
        backend.put("a", b"x")
        with pytest.raises(ObjectNotFound):
            backend.batch_get(["a", "missing"])

    def test_batch_put_roundtrip(self, backend):
        items = [(f"v/1/{i}.tvc", f"payload-{i}".encode())
                 for i in range(20)]
        backend.batch_put(items)
        assert backend.batch_get([k for k, _ in items]) \
            == [d for _, d in items]
        backend.batch_put([("v/1/0.tvc", b"overwritten")])  # overwrite ok
        assert backend.get("v/1/0.tvc") == b"overwritten"

    def test_batch_put_empty_noop(self, backend):
        backend.batch_put([])
        assert backend.list() == []

    def test_list_prefix(self, backend):
        backend.put("v/1/0.tvc", b"x")
        backend.put("v/2/0.tvc", b"y")
        backend.put("w/1/0.tvc", b"z")
        assert sorted(backend.list("v/")) == ["v/1/0.tvc", "v/2/0.tvc"]
        assert sorted(backend.list()) \
            == ["v/1/0.tvc", "v/2/0.tvc", "w/1/0.tvc"]

    def test_atomic_put_visibility(self, backend):
        """Overwrite atomicity under concurrency: a reader hammering a
        key while a writer overwrites it sees only complete values —
        never a torn mix, never a disappearing key."""
        old, new = b"o" * 4096, b"n" * 8192
        backend.put("k", old)
        stop = threading.Event()
        bad = []

        def reader():
            while not stop.is_set():
                try:
                    v = backend.get("k")
                except Exception as exc:  # pragma: no cover - fail below
                    bad.append(repr(exc))
                    return
                if v != old and v != new:
                    bad.append(f"torn read of {len(v)} bytes")
                    return

        t = threading.Thread(target=reader)
        t.start()
        try:
            for i in range(20):
                backend.put("k", new if i % 2 == 0 else old)
        finally:
            stop.set()
            t.join(timeout=30.0)
        assert not bad, bad

    def test_kind_for_names_a_priced_tier(self, backend):
        from repro.core.cost import DEFAULT_IO_TABLE

        backend.put("k", b"x")
        assert backend.kind_for("k") in DEFAULT_IO_TABLE

    def test_get_range_slices(self, backend):
        data = bytes(range(256)) * 5
        backend.put("r", data)
        assert backend.get_range("r", 0, 10) == data[:10]
        assert backend.get_range("r", 100, 50) == data[100:150]
        assert backend.get_range("r", len(data) - 1, 1) == data[-1:]
        # a range past the end truncates to the tail (HTTP 206 semantics)
        assert backend.get_range("r", 1000, 10**6) == data[1000:]

    def test_get_range_rejects_bad_args(self, backend):
        backend.put("r", b"0123456789")
        for start, length in ((-1, 5), (0, 0), (0, -3)):
            with pytest.raises(ValueError):
                backend.get_range("r", start, length)
        # start at/past the end is the storage twin of HTTP 416 — every
        # backend raises the typed subclass (still a ValueError)
        with pytest.raises(RangeNotSatisfiable):
            backend.get_range("r", 10, 1)  # start at end: unsatisfiable
        with pytest.raises(RangeNotSatisfiable):
            backend.get_range("r", 99, 1)  # start past end
        with pytest.raises(RangeNotSatisfiable):
            backend.batch_get_ranges([("r", 25, 4)])

    def test_get_range_missing_key(self, backend):
        with pytest.raises(ObjectNotFound):
            backend.get_range("nope", 0, 1)

    def test_batch_get_ranges_preserves_order(self, backend):
        backend.put("a", b"abcdefgh")
        backend.put("b", b"01234567")
        got = backend.batch_get_ranges(
            [("b", 2, 3), ("a", 0, 4), ("b", 6, 99), ("a", 4, 1)]
        )
        assert got == [b"234", b"abcd", b"67", b"e"]


# ---------------------------------------------------------------------------
# backend-specific behaviour
# ---------------------------------------------------------------------------

def test_localfs_rejects_escaping_keys(tmp_path):
    b = LocalFSBackend(str(tmp_path))
    for bad in ("/abs", "../escape", "a/../../b"):
        with pytest.raises(ValueError):
            b.put(bad, b"x")


def test_localfs_atomic_leaves_no_temps(tmp_path):
    b = LocalFSBackend(str(tmp_path), fsync=True)
    for i in range(10):
        b.put(f"v/{i}.tvc", os.urandom(1000))
    for dirpath, _dirs, files in os.walk(str(tmp_path)):
        assert not [f for f in files if TEMP_MARKER in f]


def test_sharded_distribution_and_stability(tmp_path):
    b = ShardedBackend.local(str(tmp_path), 4)
    keys = [f"v/{i}/{j}.tvc" for i in range(20) for j in range(10)]
    for k in keys:
        b.put(k, k.encode())
    per_vol = [len(v.list()) for v in b.volumes]
    assert sum(per_vol) == len(keys)
    assert all(n > 0 for n in per_vol)  # every volume takes a share
    # placement is stable and routed: the owning volume holds the key
    for k in keys[:10]:
        assert b.volumes[b.volume_for(k)].exists(k)
    b.close()


def test_sharded_batch_get_fans_out(tmp_path):
    b = ShardedBackend.local(str(tmp_path), 4)
    keys = [f"k{i}" for i in range(50)]
    for i, k in enumerate(keys):
        b.put(k, bytes([i]))
    assert b.batch_get(keys) == [bytes([i]) for i in range(50)]
    b.close()


def test_sharded_batch_put_places_like_put(tmp_path):
    b = ShardedBackend.local(str(tmp_path), 4)
    items = [(f"v/{i}/0.tvc", f"data-{i}".encode()) for i in range(40)]
    b.batch_put(items)
    for k, d in items:
        # fan-out must respect the hash ring: the owning volume holds it
        assert b.volumes[b.volume_for(k)].get(k) == d
    assert all(len(v.list()) > 0 for v in b.volumes)
    b.close()


def test_tiered_batch_put_write_through(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=1 << 20)
    b.batch_put([("a", b"1"), ("b", b"2")])
    assert cold.get("a") == b"1" and cold.get("b") == b"2"  # durable copies
    assert set(b.hot_keys()) == {"a", "b"}  # and hot-admitted


def test_tiered_write_through_and_spill(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=2500)
    for i in range(10):
        b.put(f"k{i}", bytes(1000))
    assert b.hot_total_bytes <= 2500  # spill kept the hot tier bounded
    for i in range(10):
        assert cold.exists(f"k{i}")  # write-through: cold has everything
        assert b.get(f"k{i}") == bytes(1000)  # spilled keys still readable


def test_tiered_spill_follows_priority(tmp_path):
    b = TieredBackend(LocalFSBackend(str(tmp_path)), hot_bytes=2500)
    # LRU_VSS semantics: lower sequence number spills first
    prio = {"keep-a": 100.0, "keep-b": 90.0, "drop-a": 1.0, "drop-b": 2.0}
    b.set_priority_fn(lambda keys: {k: prio.get(k, 50.0) for k in keys})
    for k in prio:
        b.put(k, bytes(1000))
    hot = set(b.hot_keys())
    assert "keep-a" in hot and "keep-b" in hot
    assert "drop-a" not in hot


def test_tiered_get_promotes(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    cold.put("x", b"cold-data")
    b = TieredBackend(cold, hot_bytes=1 << 20)
    assert b.get("x") == b"cold-data"
    assert "x" in b.hot_keys()


# ---------------------------------------------------------------------------
# write-back tiering (the tiered:remote composition; remote-specific
# behaviour lives in test_remote.py, chaos in test_faults.py)
# ---------------------------------------------------------------------------

def test_writeback_put_is_deferred_then_flushed(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=1 << 20, write_back=True)
    b.put("a", b"dirty-bytes")
    assert b.get("a") == b"dirty-bytes"     # visible immediately
    assert b.stat("a").nbytes == 11
    assert "a" in b.list()                  # dirty keys listed
    b.flush()                               # durability barrier
    assert b.dirty_keys() == []
    assert cold.get("a") == b"dirty-bytes"  # cold copy landed
    b.close()


def test_writeback_spill_flushes_dirty_before_drop(tmp_path):
    """Eviction must never lose the only copy of an unuploaded object:
    a dirty victim is uploaded synchronously, then dropped."""
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=2500, write_back=True)
    for i in range(10):  # 10 KiB through a 2.5 KiB tier
        b.put(f"k{i}", bytes([i]) * 1000)
    assert b.hot_total_bytes <= 2500
    b.flush()
    for i in range(10):  # every object durable and readable
        assert b.get(f"k{i}") == bytes([i]) * 1000
        assert cold.get(f"k{i}") == bytes([i]) * 1000
    b.close()


def test_writeback_overwrite_while_flushing_keeps_last_write(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=1 << 20, write_back=True)
    for round_ in range(5):
        b.put("k", f"gen-{round_}".encode())
    b.flush()
    assert cold.get("k") == b"gen-4"
    b.close()


def test_writeback_delete_beats_trailing_flush(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=1 << 20, write_back=True)
    b.put("k", b"x" * 100)
    b.delete("k")  # may race the background upload; delete must win
    b.flush()
    assert not b.exists("k")
    assert not cold.exists("k")
    b.close()


def test_writeback_close_is_a_durability_barrier(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=1 << 20, write_back=True)
    b.batch_put([(f"k{i}", bytes(100)) for i in range(8)])
    b.close()  # implies flush()
    assert all(cold.exists(f"k{i}") for i in range(8))


def test_oversized_overwrite_invalidates_stale_hot_copy(tmp_path):
    """An object that outgrew the hot tier bypasses admission — but a
    smaller hot copy from an earlier write must not keep serving."""
    cold = LocalFSBackend(str(tmp_path))
    for wb in (False, True):
        b = TieredBackend(cold, hot_bytes=100, write_back=wb)
        b.put("k", b"small")
        b.put("k", b"X" * 200)  # > hot_bytes: cold-only
        assert b.get("k") == b"X" * 200
        assert b.stat("k").nbytes == 200
        b.batch_put([("k", b"Y" * 300)])
        assert b.get("k") == b"Y" * 300
        b.close()


def test_writeback_ingest_window_lands_cold_before_indexing(tmp_path):
    """The ingest durability contract survives the write-back cache:
    after VSSWriter.close(), every indexed GOP object is already on
    the cold tier — a crash that wipes the hot tier loses nothing that
    was acknowledged."""
    from repro.core.store import VSS
    from repro.data.video import synthesize_road

    clip = synthesize_road(30, width=96, height=64, seed=4)
    root = str(tmp_path / "vss")
    vss = VSS(root, backend="tiered:remote")
    w = vss.writer("cam", fps=30.0, codec="tvc-ll", gop_frames=10)
    w.append(clip)
    w.close()  # durability barrier: durable AND indexed
    cold = vss.backend.cold
    gops = [g for g in vss.catalog.all_gops() if g.joint_ref is None]
    assert gops
    # deterministically on the cold tier NOW — not whenever the
    # background flusher gets to it
    assert all(cold.exists(g.path) for g in gops)
    # crash that loses the entire hot tier: reads still serve via cold
    vss.backend._drop_hot()
    out = vss.read("cam", cache=False).frames
    assert np.array_equal(out, clip)
    vss.close()


def test_writeback_spill_flush_failure_is_transient_not_terminal(
        tmp_path):
    """One cold-tier hiccup during an eviction-forced flush must not
    terminally pin the key: the attempt counts against the same
    retry budget the background flusher uses, and the key flushes
    once the cold tier recovers."""
    class Hiccup(MemoryBackend):
        def __init__(self):
            super().__init__()
            self.fail_puts = 0

        def put(self, key, data):
            if self.fail_puts > 0:
                self.fail_puts -= 1
                raise IOError("transient cold-tier hiccup")
            super().put(key, data)

    cold = Hiccup()
    b = TieredBackend(cold, hot_bytes=2500, write_back=True)
    b.put("k0", bytes(1000))
    b.flush()
    cold.fail_puts = 1  # exactly one failure, then healthy
    for i in range(1, 6):  # force spills through the failure window
        b.put(f"k{i}", bytes(1000))
    b.flush()  # must succeed: one hiccup < FLUSH_MAX_ATTEMPTS
    for i in range(6):
        assert cold.get(f"k{i}") == bytes(1000)
    b.close()


class _DownCold(MemoryBackend):
    """A cold tier that refuses writes until ``down`` clears."""

    def __init__(self):
        super().__init__()
        self.down = True

    def put(self, key, data):
        if self.down:
            raise IOError("cold tier unreachable")
        super().put(key, data)


def test_writeback_flush_failure_pins_object_hot(tmp_path):
    cold = _DownCold()
    b = TieredBackend(cold, hot_bytes=1 << 20, write_back=True)
    b.put("k", b"precious")
    with pytest.raises(RuntimeError, match="write-back flush failed"):
        b.flush()
    assert b.get("k") == b"precious"  # never dropped
    cold.down = False
    b.put("k", b"precious")  # fresh write clears the failure state
    b.flush()
    assert cold.get("k") == b"precious"
    b.close()


def test_writeback_hot_hit_range_past_end_is_typed(tmp_path):
    """A ranged read answered from the write-back hot tier must raise
    the same typed `RangeNotSatisfiable` the cold backends map to HTTP
    416 — not a bare ValueError the serving layer can't route."""
    b = TieredBackend(MemoryBackend(), hot_bytes=1 << 20, write_back=True)
    b.put("k", b"0123456789")  # acknowledged: dirty, served hot
    with pytest.raises(RangeNotSatisfiable) as ei:
        b.get_range("k", 10, 1)
    assert (ei.value.key, ei.value.start, ei.value.size) == ("k", 10, 10)
    with pytest.raises(RangeNotSatisfiable):
        b.batch_get_ranges([("k", 0, 2), ("k", 99, 1)])
    b.close()


def test_demote_surfaces_pinned_keys_during_cold_outage(tmp_path):
    """demote() during a cold-tier outage must not silently swallow the
    flush failure: pinned keys stay hot (no data loss), are counted on
    vss_cache_demote_pinned_total, and show up in stats() until the
    cold tier recovers."""
    cold = _DownCold()
    b = TieredBackend(cold, hot_bytes=1 << 20, write_back=True)
    b.put("k1", b"a" * 64)
    b.put("k2", b"b" * 64)
    before = b._c_demote_pinned.value
    assert b.demote(["k1", "k2"]) == 0  # nothing dropped, nothing lost
    assert b._c_demote_pinned.value == before + 2
    st = b.stats()
    assert st["demote_skipped_pinned"] == ["k1", "k2"]
    assert st["pinned_keys"] == ["k1", "k2"]
    assert b.get("k1") == b"a" * 64  # the acknowledged values survive
    # recovery: un-pin, flush, and the demote now lands cleanly
    cold.down = False
    assert b.retry_failed() == 2
    b.flush()
    assert b.stats()["demote_skipped_pinned"] == []
    assert b.demote(["k1", "k2"]) == 2
    assert cold.get("k1") == b"a" * 64
    assert cold.get("k2") == b"b" * 64
    b.close()


def test_writeback_oversized_overwrite_never_loses_acknowledged_value(
        tmp_path):
    """Degrading an oversized overwrite to write-through must not
    destroy the previously acknowledged dirty value until the cold put
    has succeeded — and on failure the old value stays readable AND
    durable-trackable."""
    cold = _DownCold()
    cold.down = False
    b = TieredBackend(cold, hot_bytes=100, write_back=True)
    b.put("k", b"small")          # acknowledged; may still be hot-only
    cold.down = True
    with pytest.raises(IOError):
        b.put("k", b"X" * 200)    # oversize: must write through; fails
    assert b.get("k") == b"small"  # the acknowledged value survives
    cold.down = False
    b.flush()                      # ...and still reaches durability
    assert cold.get("k") == b"small"
    b.put("k", b"X" * 200)         # healthy: the overwrite lands
    assert b.get("k") == b"X" * 200
    assert cold.get("k") == b"X" * 200
    b.flush()
    b.close()


def test_writeback_flush_scope_covers_only_named_keys(tmp_path):
    """flush(keys=...) — the per-ingest-window barrier — lands exactly
    the named keys without waiting on the rest of the dirty set."""
    cold = MemoryBackend()
    b = TieredBackend(cold, hot_bytes=1 << 20, write_back=True)
    b.batch_put([(f"w/{i}", bytes([i]) * 100) for i in range(6)])
    window = [f"w/{i}" for i in range(3)]
    b.flush(window)
    assert all(cold.get(k) == bytes([int(k[2:])]) * 100 for k in window)
    b.flush()  # global barrier still lands everything else
    assert sorted(cold.list()) == sorted(f"w/{i}" for i in range(6))
    b.close()


def test_writeback_outage_backpressures_instead_of_growing(tmp_path):
    """Cold tier down + tier over budget with pinned objects: put must
    fail (honest backpressure), not absorb dirty bytes at memory speed
    until the process OOMs."""
    cold = _DownCold()
    b = TieredBackend(cold, hot_bytes=2500, write_back=True)
    with pytest.raises(RuntimeError, match="over budget .* pinned"):
        for i in range(50):  # outage: eventually the tier must refuse
            b.put(f"k{i}", bytes(1000))
    assert b.hot_total_bytes < 50 * 1000  # growth stopped early
    # recovery: un-pin, flush, and the accepted objects all land
    cold.down = False
    assert b.retry_failed() > 0
    b.flush()
    for k in b.list():
        assert cold.get(k) == bytes(1000)
    b.close()


def test_writeback_close_retries_after_cold_tier_recovers(tmp_path):
    """Objects pinned during an outage get one more chance at close():
    the cold tier recovered, so close lands them instead of raising."""
    cold = _DownCold()
    b = TieredBackend(cold, hot_bytes=1 << 20, write_back=True)
    b.put("k", b"precious")
    with pytest.raises(RuntimeError):
        b.flush()  # pinned while down
    cold.down = False
    b.close()  # retry_failed + flush: durable after all
    assert cold.get("k") == b"precious"


def test_make_backend_specs(tmp_path):
    # make_backend wraps every composition level with telemetry, so
    # isinstance dispatch goes through unwrap(); plain attribute access
    # (.fsync, .cold, .write_back) delegates transparently
    root = str(tmp_path / "o")
    assert unwrap(make_backend("memory", root), MemoryBackend) is not None
    assert unwrap(make_backend("local", root), LocalFSBackend) is not None
    assert make_backend("local:fsync", root).fsync
    sh = make_backend("sharded:3", root)
    assert unwrap(sh, ShardedBackend) is not None and len(sh.volumes) == 3
    t = make_backend("tiered:sharded:2", root)
    assert unwrap(t, TieredBackend) is not None and not t.write_back
    assert unwrap(t.cold, ShardedBackend) is not None
    assert len(t.cold.volumes) == 2
    r = make_backend("remote", root + "r")
    assert unwrap(r, RemoteBackend) is not None
    r.close()
    tr = make_backend("tiered:remote", root + "tr")
    assert unwrap(tr, TieredBackend) is not None and tr.write_back
    assert unwrap(tr.cold, RemoteBackend) is not None
    tr.close()
    with pytest.raises(ValueError):
        make_backend("s3", root)
    with pytest.raises(ValueError):
        make_backend("remote:ftp://bad", root)
    # uninstrumented build keeps the bare types
    assert isinstance(
        make_backend("memory", root, instrument=False), MemoryBackend
    )


# ---------------------------------------------------------------------------
# VSS integration: every backend serves the full read/write pipeline
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def short_clip():
    from repro.data.video import synthesize_road

    return synthesize_road(30, width=128, height=96, seed=3)


@pytest.mark.parametrize("spec", BACKEND_SPECS)
def test_vss_pipeline_on_every_backend(spec, tmp_path, short_clip):
    from repro.core.store import VSS

    vss = VSS(str(tmp_path / "vss"),
              backend=_make(spec, str(tmp_path / "vss" / "objects")))
    vss.write("v", short_clip, fps=30.0, codec="tvc-hi", gop_frames=10)
    out = vss.read("v", codec="rgb").frames  # cached read → admission path
    assert out.shape == short_clip.shape
    r = vss.read("v", t=(0.2, 0.8), codec="hevc", cache=False)
    assert r.frames.shape[0] == 18
    vss.close()


@pytest.mark.parametrize("spec", BACKEND_SPECS)
def test_vss_tiled_pipeline_on_every_backend(spec, tmp_path, short_clip):
    """The tiled physical layout (rows x cols tile objects per GOP)
    must behave identically to the plain layout on every backend: full
    reads and ROI reads stitch the tiles back bit-exactly."""
    from repro.core.spec import WriteSpec
    from repro.core.store import VSS

    vss = VSS(str(tmp_path / "vss"),
              backend=_make(spec, str(tmp_path / "vss" / "objects")))
    w = vss.writer_spec(WriteSpec(name="v", fps=30.0, codec="tvc-hi",
                                  gop_frames=10, tiles=(2, 2)))
    w.append(short_clip)
    w.close()
    full = vss.read("v", codec="rgb", cache=False).frames
    assert full.shape == short_clip.shape
    roi = (40, 24, 88, 72)
    r = vss.read("v", roi=roi, codec="rgb", cache=False).frames
    assert np.array_equal(r, full[:, 24:72, 40:88])
    vss.close()


def test_vss_env_backend_selection(tmp_path, short_clip, monkeypatch):
    from repro.core.store import VSS
    from repro.storage import ENV_VAR

    monkeypatch.setenv(ENV_VAR, "sharded:2")
    vss = VSS(str(tmp_path / "vss"))
    assert unwrap(vss.backend, ShardedBackend) is not None
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    assert np.asarray(vss.read("v", codec="rgb", cache=False).frames).shape \
        == short_clip.shape
    vss.close()


def test_no_raw_open_on_payload_paths():
    """Acceptance guard: GOP payload I/O must live in repro.storage."""
    import pathlib

    core = pathlib.Path(__file__).parent.parent / "src" / "repro" / "core"
    offenders = []
    for f in core.glob("*.py"):
        src = f.read_text()
        if "open(" in src.replace("logical_exists(", "").replace(
                "os.open(", ""):
            for i, line in enumerate(src.splitlines(), 1):
                if "open(" in line and "os.open" not in line \
                        and "logical_exists" not in line \
                        and not line.strip().startswith("#"):
                    offenders.append(f"{f.name}:{i}: {line.strip()}")
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# crash recovery
# ---------------------------------------------------------------------------

def _fs_path_for(root, key):
    return os.path.join(root, "objects", *key.split("/"))


def test_crash_recovery_scavenges_and_preserves_committed(tmp_path,
                                                          short_clip):
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    # pinned to the local layout: the test tears objects behind the
    # store's back at known filesystem paths
    vss = VSS(root, backend="local")
    vss.write("v", short_clip, fps=30.0, codec="tvc-hi", gop_frames=10)
    vss.read("v", t=(0.0, 0.6), codec="tvc-med")  # cache a derived view
    view_gops = [
        g for p in vss.catalog.physicals_for("v") if not p.is_original
        for g in vss.catalog.gops_for(p.physical_id)
    ]
    assert view_gops
    victim = view_gops[0]
    n_gops_before = len(vss.catalog.all_gops())
    vss.catalog.close()  # crash: no clean-shutdown marker is written

    # simulate a crash's aftermath behind the store's back:
    vpath = _fs_path_for(root, victim.path)
    with open(vpath, "r+b") as f:  # torn object under a live key
        f.truncate(max(os.path.getsize(vpath) // 2, 8))
    orphan = _fs_path_for(root, "v/9/0.tvc")  # object with no catalog row
    os.makedirs(os.path.dirname(orphan), exist_ok=True)
    with open(orphan, "wb") as f:
        f.write(b"orphan")
    with open(orphan + TEMP_MARKER + "999-0", "wb") as f:
        f.write(b"partial")  # in-flight temp artifact

    vss2 = VSS(root, backend="local")  # startup scavenger runs here
    rep = vss2.recovery
    assert rep.temps_removed == 1
    assert rep.orphans_removed == 1
    assert rep.gops_dropped == 1
    assert not os.path.exists(orphan)
    # the torn object is gone from catalog and disk
    assert len(vss2.catalog.all_gops()) == n_gops_before - 1
    assert not os.path.exists(vpath)
    # committed GOPs survive: the full original still reads back exactly
    out = vss2.read("v", codec="rgb", cache=False).frames
    from repro.core.quality import exact_psnr

    assert out.shape == short_clip.shape
    assert exact_psnr(out, short_clip) >= 48.0  # tvc-hi quality intact
    vss2.close()


def test_recovery_repairs_stale_deferred_size(tmp_path, short_clip):
    """Crash between the deferred compressor's put and its catalog size
    update: object is valid (wrapped, smaller) but nbytes is stale —
    the scavenger repairs the row instead of dropping it."""
    from repro.core.deferred import wrap_bytes
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="local")  # persistence-dependent reopen below
    vss.write("v", short_clip, fps=30.0, codec="rgb", gop_frames=10)
    g = vss.catalog.gops_for(vss.catalog.get_original_id("v"))[0]
    raw = vss.backend.get(g.path)
    vss.backend.put(g.path, wrap_bytes(raw, 3))  # ...crash before update
    vss.catalog.close()  # crash: no clean-shutdown marker is written

    vss2 = VSS(root, backend="local")
    assert vss2.recovery.gops_repaired == 1
    assert vss2.recovery.gops_dropped == 0
    g2 = vss2.catalog.get_gop(g.gop_id)
    assert g2.zwrapped and g2.nbytes < len(raw)
    out = vss2.read("v", codec="rgb", cache=False).frames
    assert np.array_equal(out, short_clip)  # rgb+lossless wrap: bit-exact
    vss2.close()


def test_recovery_clean_on_healthy_store(tmp_path, short_clip):
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root)
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.close()
    vss2 = VSS(root)  # clean shutdown: the O(objects) sweep is skipped
    assert vss2.recovery.clean
    vss2.close()


def test_crash_reopen_without_close_runs_scavenger(tmp_path, short_clip):
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="local")  # persistence-dependent reopen below
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.backend.put("v/orphan.tvc", b"debris")  # no catalog row
    vss.catalog.close()  # crash
    vss2 = VSS(root, backend="local")
    assert vss2.recovery.orphans_removed == 1
    vss2.close()


def test_layout_mismatch_refuses_to_open(tmp_path, short_clip):
    """A mismatched backend must fail loudly, not scavenge-wipe the
    catalog of a healthy store."""
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="local")  # pinned: the mismatches are the point
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.close()
    with pytest.raises(ValueError, match="storage layout"):
        VSS(root, backend="sharded:2")
    with pytest.raises(ValueError, match="storage layout"):
        VSS(root, backend=MemoryBackend())
    with pytest.raises(ValueError, match="storage layout"):
        VSS(root, backend="replicated:3")
    vss2 = VSS(root, backend="local")  # original layout still opens fine
    assert vss2.read("v", codec="rgb", cache=False).frames.shape \
        == short_clip.shape
    vss2.close()


def test_tiered_layout_interchangeable_with_cold(tmp_path, short_clip):
    """The hot tier is ephemeral — tiered-over-local and plain local
    share a placement scheme and may reopen each other's stores."""
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="local")
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.close()
    vss2 = VSS(root, backend="tiered:local")
    assert vss2.read("v", codec="rgb", cache=False).frames.shape \
        == short_clip.shape
    vss2.close()


def test_drop_frees_joint_segments_only_at_last_referent(vss, overlap_pair):
    left, right, _ = overlap_pair
    vss.write("cam_a", left, fps=30.0, codec="rgb", gop_frames=left.shape[0])
    vss.write("cam_b", right, fps=30.0, codec="rgb",
              gop_frames=right.shape[0])
    jids = vss.apply_joint_compression(["cam_a", "cam_b"])
    assert jids
    seg_keys = vss.catalog.all_joint_segment_paths()
    assert seg_keys and all(vss.backend.exists(k) for k in seg_keys)
    vss.drop("cam_a")  # partner still reads through the shared pieces
    assert all(vss.backend.exists(k) for k in seg_keys)
    vss.drop("cam_b")  # last referent: pieces and joint rows are freed
    assert not any(vss.backend.exists(k) for k in seg_keys)
    assert not vss.catalog.all_joint_segment_paths()


# ---------------------------------------------------------------------------
# zlib fallback (the no-zstandard environment)
# ---------------------------------------------------------------------------

def test_wrap_roundtrip_without_zstd(monkeypatch):
    from repro.core import deferred

    monkeypatch.setattr(deferred, "zstandard", None)
    data = b"y" * 5000 + bytes(range(256))
    w = deferred.wrap_bytes(data, 5)
    assert w[:4] == deferred.LMAGIC
    assert deferred.is_wrapped(w)
    # decode side needs no zstd either way for zlib-wrapped data
    assert deferred.unwrap_bytes(w) == data


def test_codec_roundtrip_without_zstd(monkeypatch, short_clip):
    from repro.codec import tvc

    monkeypatch.setattr(tvc, "zstandard", None)
    enc = tvc.encode_gop(short_clip[:8], "tvc-hi")
    out = tvc.decode_gop(enc)
    assert out.shape == short_clip[:8].shape
    # serialized form round-trips through the normal object path
    assert tvc.decode_gop(tvc.deserialize_gop(tvc.serialize_gop(enc))).shape \
        == out.shape


def test_validate_gop_bytes_detects_truncation(short_clip):
    from repro.codec import tvc
    from repro.storage import validate_gop_bytes

    data = tvc.serialize_gop(tvc.encode_gop(short_clip[:8], "tvc-med"))
    assert validate_gop_bytes(data)
    assert not validate_gop_bytes(data[: len(data) // 2])
    assert not validate_gop_bytes(b"garbage")
