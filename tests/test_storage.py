"""`repro.storage`: backend conformance, sharding, tiering, recovery."""
import os

import numpy as np
import pytest

from repro.storage import (
    LocalFSBackend,
    MemoryBackend,
    ObjectNotFound,
    ReplicatedBackend,
    ShardedBackend,
    TieredBackend,
    make_backend,
)
from repro.storage.localfs import TEMP_MARKER

BACKEND_SPECS = ("memory", "local", "local:fsync", "sharded2", "sharded4",
                 "tiered", "replicated3", "replicated4r3")


def _make(spec, root):
    if spec == "memory":
        return MemoryBackend()
    if spec == "local":
        return LocalFSBackend(root)
    if spec == "local:fsync":
        return LocalFSBackend(root, fsync=True)
    if spec == "sharded2":
        return ShardedBackend.local(root, 2)
    if spec == "sharded4":
        return ShardedBackend.local(root, 4)
    if spec == "tiered":
        return TieredBackend(LocalFSBackend(root), hot_bytes=1 << 20)
    if spec == "replicated3":
        return ReplicatedBackend.local(root, 3)
    if spec == "replicated4r3":
        return ReplicatedBackend.local(root, 4, replicas=3, write_quorum=2)
    raise AssertionError(spec)


@pytest.fixture(params=BACKEND_SPECS)
def backend(request, tmp_path):
    b = _make(request.param, str(tmp_path / "objects"))
    yield b
    b.close()


# ---------------------------------------------------------------------------
# conformance suite — every backend, same contract
# ---------------------------------------------------------------------------

def test_put_get_roundtrip(backend):
    backend.put("v/1/0.tvc", b"alpha")
    assert backend.get("v/1/0.tvc") == b"alpha"
    backend.put("v/1/0.tvc", b"beta")  # overwrite
    assert backend.get("v/1/0.tvc") == b"beta"


def test_missing_key_raises(backend):
    with pytest.raises(ObjectNotFound):
        backend.get("nope")
    with pytest.raises(ObjectNotFound):
        backend.stat("nope")


def test_delete_idempotent(backend):
    backend.put("k", b"x")
    backend.delete("k")
    backend.delete("k")  # second delete is a no-op
    assert not backend.exists("k")


def test_stat_sizes(backend):
    backend.put("a", b"12345")
    assert backend.stat("a").nbytes == 5


def test_batch_get_preserves_order(backend):
    keys = [f"v/1/{i}.tvc" for i in range(20)]
    for i, k in enumerate(keys):
        backend.put(k, f"payload-{i}".encode())
    got = backend.batch_get(list(reversed(keys)))
    assert got == [f"payload-{i}".encode() for i in reversed(range(20))]


def test_batch_get_missing_raises(backend):
    backend.put("a", b"x")
    with pytest.raises(ObjectNotFound):
        backend.batch_get(["a", "missing"])


def test_batch_put_roundtrip(backend):
    items = [(f"v/1/{i}.tvc", f"payload-{i}".encode()) for i in range(20)]
    backend.batch_put(items)
    assert backend.batch_get([k for k, _ in items]) == [d for _, d in items]
    backend.batch_put([("v/1/0.tvc", b"overwritten")])  # overwrite allowed
    assert backend.get("v/1/0.tvc") == b"overwritten"


def test_batch_put_empty_noop(backend):
    backend.batch_put([])
    assert backend.list() == []


def test_list_prefix(backend):
    backend.put("v/1/0.tvc", b"x")
    backend.put("v/2/0.tvc", b"y")
    backend.put("w/1/0.tvc", b"z")
    assert sorted(backend.list("v/")) == ["v/1/0.tvc", "v/2/0.tvc"]
    assert sorted(backend.list()) == ["v/1/0.tvc", "v/2/0.tvc", "w/1/0.tvc"]


# ---------------------------------------------------------------------------
# backend-specific behaviour
# ---------------------------------------------------------------------------

def test_localfs_rejects_escaping_keys(tmp_path):
    b = LocalFSBackend(str(tmp_path))
    for bad in ("/abs", "../escape", "a/../../b"):
        with pytest.raises(ValueError):
            b.put(bad, b"x")


def test_localfs_atomic_leaves_no_temps(tmp_path):
    b = LocalFSBackend(str(tmp_path), fsync=True)
    for i in range(10):
        b.put(f"v/{i}.tvc", os.urandom(1000))
    for dirpath, _dirs, files in os.walk(str(tmp_path)):
        assert not [f for f in files if TEMP_MARKER in f]


def test_sharded_distribution_and_stability(tmp_path):
    b = ShardedBackend.local(str(tmp_path), 4)
    keys = [f"v/{i}/{j}.tvc" for i in range(20) for j in range(10)]
    for k in keys:
        b.put(k, k.encode())
    per_vol = [len(v.list()) for v in b.volumes]
    assert sum(per_vol) == len(keys)
    assert all(n > 0 for n in per_vol)  # every volume takes a share
    # placement is stable and routed: the owning volume holds the key
    for k in keys[:10]:
        assert b.volumes[b.volume_for(k)].exists(k)
    b.close()


def test_sharded_batch_get_fans_out(tmp_path):
    b = ShardedBackend.local(str(tmp_path), 4)
    keys = [f"k{i}" for i in range(50)]
    for i, k in enumerate(keys):
        b.put(k, bytes([i]))
    assert b.batch_get(keys) == [bytes([i]) for i in range(50)]
    b.close()


def test_sharded_batch_put_places_like_put(tmp_path):
    b = ShardedBackend.local(str(tmp_path), 4)
    items = [(f"v/{i}/0.tvc", f"data-{i}".encode()) for i in range(40)]
    b.batch_put(items)
    for k, d in items:
        # fan-out must respect the hash ring: the owning volume holds it
        assert b.volumes[b.volume_for(k)].get(k) == d
    assert all(len(v.list()) > 0 for v in b.volumes)
    b.close()


def test_tiered_batch_put_write_through(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=1 << 20)
    b.batch_put([("a", b"1"), ("b", b"2")])
    assert cold.get("a") == b"1" and cold.get("b") == b"2"  # durable copies
    assert set(b.hot_keys()) == {"a", "b"}  # and hot-admitted


def test_tiered_write_through_and_spill(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    b = TieredBackend(cold, hot_bytes=2500)
    for i in range(10):
        b.put(f"k{i}", bytes(1000))
    assert b.hot_total_bytes <= 2500  # spill kept the hot tier bounded
    for i in range(10):
        assert cold.exists(f"k{i}")  # write-through: cold has everything
        assert b.get(f"k{i}") == bytes(1000)  # spilled keys still readable


def test_tiered_spill_follows_priority(tmp_path):
    b = TieredBackend(LocalFSBackend(str(tmp_path)), hot_bytes=2500)
    # LRU_VSS semantics: lower sequence number spills first
    prio = {"keep-a": 100.0, "keep-b": 90.0, "drop-a": 1.0, "drop-b": 2.0}
    b.set_priority_fn(lambda keys: {k: prio.get(k, 50.0) for k in keys})
    for k in prio:
        b.put(k, bytes(1000))
    hot = set(b.hot_keys())
    assert "keep-a" in hot and "keep-b" in hot
    assert "drop-a" not in hot


def test_tiered_get_promotes(tmp_path):
    cold = LocalFSBackend(str(tmp_path))
    cold.put("x", b"cold-data")
    b = TieredBackend(cold, hot_bytes=1 << 20)
    assert b.get("x") == b"cold-data"
    assert "x" in b.hot_keys()


def test_make_backend_specs(tmp_path):
    root = str(tmp_path / "o")
    assert isinstance(make_backend("memory", root), MemoryBackend)
    assert isinstance(make_backend("local", root), LocalFSBackend)
    assert make_backend("local:fsync", root).fsync
    sh = make_backend("sharded:3", root)
    assert isinstance(sh, ShardedBackend) and len(sh.volumes) == 3
    t = make_backend("tiered:sharded:2", root)
    assert isinstance(t, TieredBackend)
    assert isinstance(t.cold, ShardedBackend) and len(t.cold.volumes) == 2
    with pytest.raises(ValueError):
        make_backend("s3", root)


# ---------------------------------------------------------------------------
# VSS integration: every backend serves the full read/write pipeline
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def short_clip():
    from repro.data.video import synthesize_road

    return synthesize_road(30, width=128, height=96, seed=3)


@pytest.mark.parametrize("spec", BACKEND_SPECS)
def test_vss_pipeline_on_every_backend(spec, tmp_path, short_clip):
    from repro.core.store import VSS

    vss = VSS(str(tmp_path / "vss"),
              backend=_make(spec, str(tmp_path / "vss" / "objects")))
    vss.write("v", short_clip, fps=30.0, codec="tvc-hi", gop_frames=10)
    out = vss.read("v", codec="rgb").frames  # cached read → admission path
    assert out.shape == short_clip.shape
    r = vss.read("v", t=(0.2, 0.8), codec="hevc", cache=False)
    assert r.frames.shape[0] == 18
    vss.close()


def test_vss_env_backend_selection(tmp_path, short_clip, monkeypatch):
    from repro.core.store import VSS
    from repro.storage import ENV_VAR

    monkeypatch.setenv(ENV_VAR, "sharded:2")
    vss = VSS(str(tmp_path / "vss"))
    assert isinstance(vss.backend, ShardedBackend)
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    assert np.asarray(vss.read("v", codec="rgb", cache=False).frames).shape \
        == short_clip.shape
    vss.close()


def test_no_raw_open_on_payload_paths():
    """Acceptance guard: GOP payload I/O must live in repro.storage."""
    import pathlib

    core = pathlib.Path(__file__).parent.parent / "src" / "repro" / "core"
    offenders = []
    for f in core.glob("*.py"):
        src = f.read_text()
        if "open(" in src.replace("logical_exists(", "").replace(
                "os.open(", ""):
            for i, line in enumerate(src.splitlines(), 1):
                if "open(" in line and "os.open" not in line \
                        and "logical_exists" not in line \
                        and not line.strip().startswith("#"):
                    offenders.append(f"{f.name}:{i}: {line.strip()}")
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# crash recovery
# ---------------------------------------------------------------------------

def _fs_path_for(root, key):
    return os.path.join(root, "objects", *key.split("/"))


def test_crash_recovery_scavenges_and_preserves_committed(tmp_path,
                                                          short_clip):
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    # pinned to the local layout: the test tears objects behind the
    # store's back at known filesystem paths
    vss = VSS(root, backend="local")
    vss.write("v", short_clip, fps=30.0, codec="tvc-hi", gop_frames=10)
    vss.read("v", t=(0.0, 0.6), codec="tvc-med")  # cache a derived view
    view_gops = [
        g for p in vss.catalog.physicals_for("v") if not p.is_original
        for g in vss.catalog.gops_for(p.physical_id)
    ]
    assert view_gops
    victim = view_gops[0]
    n_gops_before = len(vss.catalog.all_gops())
    vss.catalog.close()  # crash: no clean-shutdown marker is written

    # simulate a crash's aftermath behind the store's back:
    vpath = _fs_path_for(root, victim.path)
    with open(vpath, "r+b") as f:  # torn object under a live key
        f.truncate(max(os.path.getsize(vpath) // 2, 8))
    orphan = _fs_path_for(root, "v/9/0.tvc")  # object with no catalog row
    os.makedirs(os.path.dirname(orphan), exist_ok=True)
    with open(orphan, "wb") as f:
        f.write(b"orphan")
    with open(orphan + TEMP_MARKER + "999-0", "wb") as f:
        f.write(b"partial")  # in-flight temp artifact

    vss2 = VSS(root, backend="local")  # startup scavenger runs here
    rep = vss2.recovery
    assert rep.temps_removed == 1
    assert rep.orphans_removed == 1
    assert rep.gops_dropped == 1
    assert not os.path.exists(orphan)
    # the torn object is gone from catalog and disk
    assert len(vss2.catalog.all_gops()) == n_gops_before - 1
    assert not os.path.exists(vpath)
    # committed GOPs survive: the full original still reads back exactly
    out = vss2.read("v", codec="rgb", cache=False).frames
    from repro.core.quality import exact_psnr

    assert out.shape == short_clip.shape
    assert exact_psnr(out, short_clip) >= 48.0  # tvc-hi quality intact
    vss2.close()


def test_recovery_repairs_stale_deferred_size(tmp_path, short_clip):
    """Crash between the deferred compressor's put and its catalog size
    update: object is valid (wrapped, smaller) but nbytes is stale —
    the scavenger repairs the row instead of dropping it."""
    from repro.core.deferred import wrap_bytes
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="local")  # persistence-dependent reopen below
    vss.write("v", short_clip, fps=30.0, codec="rgb", gop_frames=10)
    g = vss.catalog.gops_for(vss.catalog.get_original_id("v"))[0]
    raw = vss.backend.get(g.path)
    vss.backend.put(g.path, wrap_bytes(raw, 3))  # ...crash before update
    vss.catalog.close()  # crash: no clean-shutdown marker is written

    vss2 = VSS(root, backend="local")
    assert vss2.recovery.gops_repaired == 1
    assert vss2.recovery.gops_dropped == 0
    g2 = vss2.catalog.get_gop(g.gop_id)
    assert g2.zwrapped and g2.nbytes < len(raw)
    out = vss2.read("v", codec="rgb", cache=False).frames
    assert np.array_equal(out, short_clip)  # rgb+lossless wrap: bit-exact
    vss2.close()


def test_recovery_clean_on_healthy_store(tmp_path, short_clip):
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root)
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.close()
    vss2 = VSS(root)  # clean shutdown: the O(objects) sweep is skipped
    assert vss2.recovery.clean
    vss2.close()


def test_crash_reopen_without_close_runs_scavenger(tmp_path, short_clip):
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="local")  # persistence-dependent reopen below
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.backend.put("v/orphan.tvc", b"debris")  # no catalog row
    vss.catalog.close()  # crash
    vss2 = VSS(root, backend="local")
    assert vss2.recovery.orphans_removed == 1
    vss2.close()


def test_layout_mismatch_refuses_to_open(tmp_path, short_clip):
    """A mismatched backend must fail loudly, not scavenge-wipe the
    catalog of a healthy store."""
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="local")  # pinned: the mismatches are the point
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.close()
    with pytest.raises(ValueError, match="storage layout"):
        VSS(root, backend="sharded:2")
    with pytest.raises(ValueError, match="storage layout"):
        VSS(root, backend=MemoryBackend())
    with pytest.raises(ValueError, match="storage layout"):
        VSS(root, backend="replicated:3")
    vss2 = VSS(root, backend="local")  # original layout still opens fine
    assert vss2.read("v", codec="rgb", cache=False).frames.shape \
        == short_clip.shape
    vss2.close()


def test_tiered_layout_interchangeable_with_cold(tmp_path, short_clip):
    """The hot tier is ephemeral — tiered-over-local and plain local
    share a placement scheme and may reopen each other's stores."""
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="local")
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.close()
    vss2 = VSS(root, backend="tiered:local")
    assert vss2.read("v", codec="rgb", cache=False).frames.shape \
        == short_clip.shape
    vss2.close()


def test_drop_frees_joint_segments_only_at_last_referent(vss, overlap_pair):
    left, right, _ = overlap_pair
    vss.write("cam_a", left, fps=30.0, codec="rgb", gop_frames=left.shape[0])
    vss.write("cam_b", right, fps=30.0, codec="rgb",
              gop_frames=right.shape[0])
    jids = vss.apply_joint_compression(["cam_a", "cam_b"])
    assert jids
    seg_keys = vss.catalog.all_joint_segment_paths()
    assert seg_keys and all(vss.backend.exists(k) for k in seg_keys)
    vss.drop("cam_a")  # partner still reads through the shared pieces
    assert all(vss.backend.exists(k) for k in seg_keys)
    vss.drop("cam_b")  # last referent: pieces and joint rows are freed
    assert not any(vss.backend.exists(k) for k in seg_keys)
    assert not vss.catalog.all_joint_segment_paths()


# ---------------------------------------------------------------------------
# zlib fallback (the no-zstandard environment)
# ---------------------------------------------------------------------------

def test_wrap_roundtrip_without_zstd(monkeypatch):
    from repro.core import deferred

    monkeypatch.setattr(deferred, "zstandard", None)
    data = b"y" * 5000 + bytes(range(256))
    w = deferred.wrap_bytes(data, 5)
    assert w[:4] == deferred.LMAGIC
    assert deferred.is_wrapped(w)
    # decode side needs no zstd either way for zlib-wrapped data
    assert deferred.unwrap_bytes(w) == data


def test_codec_roundtrip_without_zstd(monkeypatch, short_clip):
    from repro.codec import tvc

    monkeypatch.setattr(tvc, "zstandard", None)
    enc = tvc.encode_gop(short_clip[:8], "tvc-hi")
    out = tvc.decode_gop(enc)
    assert out.shape == short_clip[:8].shape
    # serialized form round-trips through the normal object path
    assert tvc.decode_gop(tvc.deserialize_gop(tvc.serialize_gop(enc))).shape \
        == out.shape


def test_validate_gop_bytes_detects_truncation(short_clip):
    from repro.codec import tvc
    from repro.storage import validate_gop_bytes

    data = tvc.serialize_gop(tvc.encode_gop(short_clip[:8], "tvc-med"))
    assert validate_gop_bytes(data)
    assert not validate_gop_bytes(data[: len(data) // 2])
    assert not validate_gop_bytes(b"garbage")
