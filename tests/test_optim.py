"""Optimizer substrate: AdamW, schedules, clipping, EF compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_decompress_grads,
    cosine_schedule,
    init_error_feedback,
    linear_warmup,
)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([4.0, -3.0], jnp.float32)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(300):
        grads = jax.tree_util.tree_map(lambda w: 2 * w, params)
        params, state = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(state["count"]) == 300


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 3.0), "b": jnp.full((5,), 4.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    from repro.optim import global_norm

    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0
    # under the limit: untouched
    small = {"a": jnp.full((4,), 1e-3)}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(out["a"], small["a"], rtol=1e-6)


def test_schedules():
    assert float(linear_warmup(0, 10)) <= 0.11
    assert float(linear_warmup(100, 10)) == 1.0
    lr0 = float(cosine_schedule(0, 1000, warmup_steps=10))
    lr_mid = float(cosine_schedule(500, 1000, warmup_steps=10))
    lr_end = float(cosine_schedule(1000, 1000, warmup_steps=10))
    assert lr0 < lr_mid  # warming up
    assert lr_end <= lr_mid
    assert lr_end >= 0.09  # min_ratio floor


def test_error_feedback_compensates_quantization():
    """Accumulated EF-compressed grads converge to accumulated true
    grads (error feedback makes quantization unbiased over time)."""
    rng = np.random.default_rng(0)
    g_true = [
        {"w": jnp.asarray(rng.normal(0, 1, (64,)).astype(np.float32))}
        for _ in range(50)
    ]
    error = init_error_feedback(g_true[0])
    acc_c = jnp.zeros((64,))
    acc_t = jnp.zeros((64,))
    for g in g_true:
        gq, error = compress_decompress_grads(g, error)
        acc_c = acc_c + gq["w"]
        acc_t = acc_t + g["w"]
    # residual error is bounded by one step's quantization, not O(T)
    resid = float(jnp.abs(acc_c - acc_t).max())
    one_step_q = float(jnp.abs(g_true[0]["w"]).max()) / 127 * 4
    assert resid < one_step_q * 2, resid


def test_bf16_param_state_roundtrip():
    from repro.configs import smoke_config
    from repro.launch.steps import TrainHyper, init_train_state

    cfg = smoke_config("phi3-mini-3.8b")
    hyper = TrainHyper(bf16_params=True, num_microbatches=1)
    state = init_train_state(jax.random.key(0), cfg, hyper)
    # live params bf16, fp32 master in the optimizer
    leaf = state["params"]["groups"]["0_attn"]["attn"]["wq"]
    assert leaf.dtype == jnp.bfloat16
    assert state["opt"]["master"]["groups"]["0_attn"]["attn"][
        "wq"
    ].dtype == jnp.float32
