"""Serving engine: paged decode parity, prefix dedup, LRU_VSS pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import model as M
from repro.models.sharding import ShardCtx
from repro.serving.engine import ServingEngine
from repro.serving.pages import PagePool, PagePoolConfig, prefix_hash

CTX = ShardCtx(None)


@pytest.fixture(scope="module")
def served():
    cfg = smoke_config("phi3-mini-3.8b")
    params = M.init_model(jax.random.key(0), cfg)
    return cfg, params


def _dense_greedy(cfg, params, prompt, n):
    cache = M.init_cache(cfg, 1, max_len=len(prompt) + n + 4)
    tok = np.asarray(prompt, np.int32)[None]
    _, cache = jax.jit(lambda p, b, c: M.prefill(p, cfg, b, c, CTX))(
        params, {"tokens": tok[:, :-1]}, cache
    )
    out, cur = [], tok[:, -1:]
    for _ in range(n):
        lg, cache = jax.jit(
            lambda p, c, t: M.decode_step(p, cfg, c, t, CTX)
        )(params, cache, jnp.asarray(cur))
        cur = [[int(jnp.argmax(lg[0, 0]))]]
        out.append(cur[0][0])
    return out


def test_paged_matches_dense(served):
    cfg, params = served
    eng = ServingEngine(cfg, params, page_size=8, num_pages=64, max_batch=4)
    prompt = list(range(40, 80))
    rid = eng.submit(prompt, max_new=8)
    done = eng.run()
    assert done[rid].out == _dense_greedy(cfg, params, prompt, 8)


def test_prefix_dedup_shares_pages(served):
    cfg, params = served
    eng = ServingEngine(cfg, params, page_size=8, num_pages=64, max_batch=4)
    prompt = list(range(100, 140))
    r1 = eng.submit(prompt, max_new=4)
    eng.run()
    r2 = eng.submit(prompt, max_new=4)  # identical prompt → full dedup
    done = eng.run()
    assert done[r2].dedup_pages >= 4
    assert done[r2].out == _dense_greedy(cfg, params, prompt, 4)
    # divergent suffix after a shared prefix
    r3 = eng.submit(prompt[:32] + [7, 7, 7, 7], max_new=4)
    done = eng.run()
    assert done[r3].dedup_pages == 4  # 32 tokens / page 8


def test_batched_decode_matches_sequential(served):
    cfg, params = served
    eng = ServingEngine(cfg, params, page_size=8, num_pages=128, max_batch=4)
    prompts = [list(range(10, 30)), list(range(200, 230)),
               list(range(55, 75))]
    rids = [eng.submit(p, max_new=5) for p in prompts]
    done = eng.run()
    for rid, p in zip(rids, prompts):
        assert done[rid].out == _dense_greedy(cfg, params, p, 5)


def test_pool_eviction_under_pressure(served):
    cfg, params = served
    eng = ServingEngine(cfg, params, page_size=8, num_pages=24, max_batch=2)
    for i in range(6):  # each run retains pages; pool must recycle
        eng.submit(list(range(i * 31, i * 31 + 24)), max_new=4)
    done = eng.run()
    assert len(done) == 6
    assert eng.pool.pages_in_use <= eng.pool.cfg.num_pages


def test_pool_refcounting():
    pool = PagePool(PagePoolConfig(
        num_pages=8, page_size=4, num_layers=1, num_kv_heads=1, head_dim=8
    ))
    a = pool.alloc()
    pool.register_prefix([1, 2, 3, 4], [a])
    shared, covered = pool.lookup_prefix([1, 2, 3, 4, 5])
    assert shared == [a] and covered == 4
    assert pool.refcount[a] == 2
    pool.release(a)
    pool.release(a)
    assert a in pool.free
    assert prefix_hash([1, 2, 3, 4]) not in pool.prefix_index
