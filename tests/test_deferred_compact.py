"""Deferred compression (§5.2) and compaction (§5.3)."""

from repro.core import compact as C
from repro.core.deferred import is_wrapped, unwrap_bytes, wrap_bytes


def test_wrap_roundtrip():
    data = b"x" * 10000 + bytes(range(256))
    w = wrap_bytes(data, 3)
    assert is_wrapped(w)
    assert unwrap_bytes(w) == data
    assert len(w) < len(data)


def test_deferred_activates_over_threshold(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi", budget_bytes=3_000_000)
    # raw read caches uncompressed views → cache fraction rises
    vss.read("v", codec="rgb")
    assert vss.deferred.active("v")
    gid = vss.deferred.compress_one("v")
    assert gid is not None
    g = vss.catalog.get_gop(gid)
    assert g.zwrapped
    assert is_wrapped(vss.backend.get(g.path))
    # wrapped GOPs decode transparently on read
    out = vss.read("v", codec="rgb", cache=False).frames
    assert out.shape == clip.shape


def test_compression_level_scales_with_usage(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi", budget_bytes=10**8)
    lvl_low = vss.deferred.current_level("v")
    vss.catalog.set_budget("v", vss.catalog.total_bytes("v"))
    lvl_high = vss.deferred.current_level("v")
    assert lvl_high > lvl_low


def test_compaction_merges_contiguous_views(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi", budget_bytes=10**9)
    vss.enable_compaction = False  # manual control
    vss.read("v", t=(0.0, 1.0), codec="tvc-med")
    vss.read("v", t=(1.0, 2.0), codec="tvc-med")
    phys_before = len(vss.catalog.physicals_for("v"))
    merged = C.compact(vss.catalog, "v", vss.backend)
    assert merged >= 1
    assert len(vss.catalog.physicals_for("v")) < phys_before
    # contiguous merged view serves the whole range
    r = vss.read("v", t=(0.0, 2.0), codec="tvc-med", cache=False)
    assert r.frames.shape[0] == 60
