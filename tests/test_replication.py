"""`ReplicatedBackend`: placement, quorum writes, fallback reads, scrub.

The backend-level tests drive the quorum/fallback machinery directly
(including a child dying MID-batch and a child marked down); the
VSS-level tests prove the §2 pipeline rides through degraded storage —
one lost child of three must never lose a GOP or fail a read — and
that the scrubber restores full replication afterwards.
"""
import os
import shutil

import numpy as np
import pytest

from repro.storage import (
    ChildDownError,
    HashRing,
    LocalFSBackend,
    MemoryBackend,
    ObjectNotFound,
    ReplicatedBackend,
    ReplicationError,
    make_backend,
    unwrap,
    validate_gop_bytes,
)


@pytest.fixture()
def rb(tmp_path):
    b = ReplicatedBackend.local(str(tmp_path / "objects"), 3)
    yield b
    b.close()


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def test_ring_preference_distinct_and_anchored():
    ring = HashRing(5)
    for key in (f"v/{i}/0.tvc" for i in range(50)):
        prefs = ring.preference(key, 3)
        assert len(prefs) == len(set(prefs)) == 3
        assert prefs[0] == ring.owner(key)
    # preference is a pure function of the slot count
    again = HashRing(5)
    assert all(
        again.preference(f"k{i}", 3) == ring.preference(f"k{i}", 3)
        for i in range(20)
    )


def test_replicas_for_spreads_over_children(rb):
    used = set()
    for i in range(60):
        prefs = rb.replicas_for(f"v/{i}/0.tvc")
        assert len(prefs) == rb.replicas == 3
        used.update(prefs)
    assert used == {0, 1, 2}


def test_put_lands_on_every_replica(rb):
    rb.put("v/1/0.tvc", b"payload")
    rb.quiesce()
    assert rb.replica_count("v/1/0.tvc") == 3
    for ci in rb.replicas_for("v/1/0.tvc"):
        assert rb.replica_get(ci, "v/1/0.tvc") == b"payload"


# ---------------------------------------------------------------------------
# quorum writes
# ---------------------------------------------------------------------------

def test_degraded_put_meets_quorum(rb):
    rb.mark_child_down(0)
    rb.put("k", b"x")
    rb.quiesce()
    assert rb.get("k") == b"x"
    assert rb.replica_count("k") == 2  # the down child holds nothing
    assert rb.stats.degraded_writes >= 1


def test_put_without_quorum_raises(rb):
    rb.mark_child_down(0)
    rb.mark_child_down(1)
    with pytest.raises(ReplicationError):
        rb.put("k", b"x")
    rb.mark_child_up(0)
    rb.put("k", b"x")  # quorum restored (W=2 of the 2 live children)
    rb.quiesce()
    assert rb.get("k") == b"x"


def test_batch_put_quorum_and_degraded(rb):
    items = [(f"v/{i}/0.tvc", f"d{i}".encode()) for i in range(12)]
    rb.mark_child_down(2)
    rb.batch_put(items)  # every object still reaches W=2 live replicas
    assert rb.batch_get([k for k, _ in items]) == [d for _, d in items]
    rb.mark_child_down(1)
    with pytest.raises(ReplicationError):
        rb.batch_put([("under-quorum", b"x")])


def test_child_down_error_is_immediate(rb):
    rb.mark_child_down(1)
    with pytest.raises(ChildDownError):
        rb.replica_get(1, "anything")
    rb.mark_child_up(1)


# ---------------------------------------------------------------------------
# read fallback
# ---------------------------------------------------------------------------

def test_get_falls_back_past_dead_child(rb):
    rb.put("v/1/0.tvc", b"survives")
    rb.quiesce()
    before = rb.stats.fallback_reads
    rb.mark_child_down(rb.replicas_for("v/1/0.tvc")[0])
    assert rb.get("v/1/0.tvc") == b"survives"
    assert rb.stats.fallback_reads > before


def test_missing_everywhere_raises_object_not_found(rb):
    with pytest.raises(ObjectNotFound):
        rb.get("nope")
    rb.mark_child_down(0)  # a down child must not mask a plain miss
    with pytest.raises(ObjectNotFound):
        rb.stat("nope")


def test_unreachable_data_is_unavailable_not_missing(rb):
    """Durable data whose live copies all sit behind down children must
    raise ReplicationError, never ObjectNotFound: absence is only
    reported when enough slots were VERIFIED empty that a quorum write
    could not be hiding on the unreachable rest."""
    rb.mark_child_down(2)
    rb.put("k", b"x")  # quorum lands on children 0 and 1 only
    rb.quiesce()
    rb.mark_child_up(2)
    rb.mark_child_down(0)
    rb.mark_child_down(1)  # the only copies are now unreachable
    with pytest.raises(ReplicationError):
        rb.get("k")
    with pytest.raises(ReplicationError):
        rb.batch_get(["k"])
    rb.mark_child_up(0)
    assert rb.get("k") == b"x"  # back as soon as one copy is reachable


class DyingChild(LocalFSBackend):
    """A child that serves ``fail_after`` gets, then dies mid-flight —
    every later op raises like a yanked disk."""

    def __init__(self, root, fail_after):
        super().__init__(root)
        self.remaining = fail_after

    def get(self, key):
        if self.remaining <= 0:
            raise OSError("disk died")
        self.remaining -= 1
        return super().get(key)


def test_batch_get_survives_child_dying_mid_batch(tmp_path):
    dying = DyingChild(str(tmp_path / "c0"), fail_after=2)
    rb = ReplicatedBackend([
        dying,
        LocalFSBackend(str(tmp_path / "c1")),
        LocalFSBackend(str(tmp_path / "c2")),
    ])
    keys = [f"v/{i}/0.tvc" for i in range(16)]
    rb.batch_put([(k, k.encode()) for k in keys])
    # the dying child is first preference for 2 of these keys: let it
    # serve ONE, then die mid-sublist — the rest must fall back
    dying.remaining = 1
    assert rb.batch_get(keys) == [k.encode() for k in keys]
    assert rb.stats.fallback_reads > 0
    rb.close()


def test_batch_get_preserves_order_while_degraded(rb):
    keys = [f"v/{i}/0.tvc" for i in range(20)]
    rb.batch_put([(k, f"p{i}".encode()) for i, k in enumerate(keys)])
    rb.mark_child_down(1)
    got = rb.batch_get(list(reversed(keys)))
    assert got == [f"p{i}".encode() for i in reversed(range(20))]


def test_kind_for_answers_per_replica(tmp_path):
    rb = ReplicatedBackend([
        MemoryBackend(),
        LocalFSBackend(str(tmp_path / "c1")),
        LocalFSBackend(str(tmp_path / "c2")),
    ])
    rb.put("k", b"x")
    rb.quiesce()
    assert rb.kind_for("k") == "memory"  # fastest live replica serves
    rb.mark_child_down(0)
    assert rb.kind_for("k") == "localfs"  # degraded read priced as disk
    rb.mark_child_up(0)
    assert rb.kind_for("k") == "memory"  # memo invalidated on recovery
    assert rb.kind_for("missing-everywhere") == "replicated"
    rb.close()


# ---------------------------------------------------------------------------
# spec / fingerprint
# ---------------------------------------------------------------------------

def test_make_backend_replicated_specs(tmp_path):
    root = str(tmp_path / "o")
    b = make_backend("replicated", root)
    # make_backend wraps with telemetry; attribute access delegates
    assert unwrap(b, ReplicatedBackend) is not None
    assert len(b.children) == 3 and b.replicas == 3 and b.write_quorum == 2
    b5 = make_backend("replicated:5", root + "5")
    assert len(b5.children) == 5 and b5.replicas == 3 and b5.write_quorum == 2
    b532 = make_backend("replicated:5:3:3", root + "532")
    assert b532.replicas == 3 and b532.write_quorum == 3
    with pytest.raises(ValueError):
        make_backend("replicated:3:2:3", root)  # W > R
    for b_ in (b, b5, b532):
        b_.close()


def test_layout_fingerprint_pins_children_and_replicas(tmp_path):
    a = ReplicatedBackend.local(str(tmp_path / "a"), 3)
    b = ReplicatedBackend.local(str(tmp_path / "b"), 3, write_quorum=3)
    c = ReplicatedBackend.local(str(tmp_path / "c"), 4)
    assert a.layout_fingerprint() == b.layout_fingerprint()  # W is not layout
    assert a.layout_fingerprint() != c.layout_fingerprint()
    for b_ in (a, b, c):
        b_.close()


# ---------------------------------------------------------------------------
# VSS end-to-end: degraded operation + scrub
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def short_clip():
    from repro.data.video import synthesize_road

    return synthesize_road(30, width=128, height=96, seed=3)


@pytest.fixture()
def rvss(tmp_path):
    from repro.core.store import VSS

    store = VSS(str(tmp_path / "vss"),
                backend=ReplicatedBackend.local(
                    str(tmp_path / "vss" / "objects"), 3))
    yield store
    store.close()


def _gop_keys(vss, name):
    return [
        g.path
        for p in vss.catalog.physicals_for(name)
        for g in vss.catalog.gops_for(p.physical_id)
        if g.joint_ref is None
    ]


def test_vss_reads_survive_any_single_child_loss(rvss, short_clip):
    rvss.write("v", short_clip, fps=30.0, codec="tvc-hi", gop_frames=10)
    rvss.backend.quiesce()
    keys = _gop_keys(rvss, "v")
    assert keys and all(
        rvss.backend.replica_count(k) == 3 for k in keys
    )
    baseline = rvss.read("v", codec="rgb", cache=False).frames
    for victim in range(3):
        rvss.backend.mark_child_down(victim)
        out = rvss.read("v", codec="rgb", cache=False).frames
        assert np.array_equal(out, baseline)
        rvss.backend.mark_child_up(victim)


def test_vss_ingest_flows_while_degraded(rvss, short_clip):
    """Quorum writes keep the pipelined ingest path alive with a child
    down: windows publish, rows index, prefix reads work."""
    rvss.backend.mark_child_down(2)
    w = rvss.writer("cam", fps=30.0, codec="tvc-med", gop_frames=10)
    w.append(short_clip)
    w.close()
    out = rvss.read("cam", codec="rgb", cache=False).frames
    assert out.shape == short_clip.shape
    rvss.backend.mark_child_up(2)
    report = rvss.scrub()  # re-replicates what the dead child missed
    assert report.replicas_repaired > 0
    assert all(
        rvss.backend.replica_count(k) == 3 for k in _gop_keys(rvss, "cam")
    )


def test_crash_between_quorum_write_and_index_collects_all_replicas(
        tmp_path, short_clip):
    """Publish-then-index: a crash after the quorum write but before the
    catalog row leaves replicas on EVERY child — the startup scrub must
    collect the orphan from all of them."""
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="replicated:3")
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    orphan = "v/9/0.tvc"
    vss.backend.put(orphan, b"published-but-never-indexed")
    vss.backend.quiesce()
    assert vss.backend.replica_count(orphan) == 3
    vss.catalog.close()  # crash: no clean-shutdown marker
    vss.backend.close()

    vss2 = VSS(root, backend="replicated:3")
    try:
        assert vss2.recovery.orphans_removed == 1
        assert all(
            not child.exists(orphan) for child in vss2.backend.children
        )
        out = vss2.read("v", codec="rgb", cache=False).frames
        assert out.shape == short_clip.shape
    finally:
        vss2.close()


def test_scrub_repairs_deliberately_corrupted_replica(rvss, short_clip):
    rvss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    rvss.backend.quiesce()
    key = _gop_keys(rvss, "v")[0]
    ci = rvss.backend.replicas_for(key)[1]
    good = rvss.backend.replica_get(ci, key)
    rvss.backend.replica_put(ci, key, good[: len(good) // 2])  # torn copy
    assert not validate_gop_bytes(rvss.backend.replica_get(ci, key))
    report = rvss.scrub()
    assert report.replicas_repaired == 1
    assert report.gops_dropped == 0
    assert rvss.backend.replica_get(ci, key) == good
    out = rvss.read("v", codec="rgb", cache=False).frames
    assert out.shape == short_clip.shape


def test_scrub_restores_replication_after_disk_replacement(rvss, short_clip):
    rvss.write("v", short_clip, fps=30.0, codec="tvc-hi", gop_frames=10)
    rvss.backend.quiesce()
    keys = _gop_keys(rvss, "v")
    child0 = rvss.backend.children[0]
    lost = [k for k in keys if 0 in rvss.backend.replicas_for(k)]
    shutil.rmtree(child0.root)  # the disk is replaced, empty
    os.makedirs(child0.root)
    report = rvss.scrub()
    assert report.replicas_repaired == len(lost) > 0
    assert report.gops_dropped == 0
    assert all(rvss.backend.replica_count(k) == 3 for k in keys)


def test_scrub_skips_unverifiable_slots_on_down_child(rvss, short_clip):
    """A down child's replicas are skipped, never condemned: no rows
    drop, and the scrub reports what it could not check."""
    rvss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    rvss.backend.quiesce()
    rvss.backend.mark_child_down(1)
    report = rvss.scrub()
    assert report.gops_dropped == 0
    assert report.replicas_skipped > 0
    assert not report.clean
    rvss.backend.mark_child_up(1)
    assert rvss.scrub().clean


def test_scrub_drops_row_only_when_every_slot_verified_empty(rvss,
                                                             short_clip):
    rvss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    rvss.backend.quiesce()
    key = _gop_keys(rvss, "v")[0]
    n_before = len(rvss.catalog.all_gops())
    for ci in rvss.backend.replicas_for(key):
        rvss.backend.replica_delete(ci, key)  # operator-level total loss
    report = rvss.scrub()
    assert report.gops_dropped == 1
    assert len(rvss.catalog.all_gops()) == n_before - 1
    # committed siblings (the later GOPs) stay readable
    out = rvss.read("v", t=(0.5, 1.0), codec="rgb", cache=False).frames
    assert out.shape[0] == 15


def test_scrub_prunes_misplaced_replica(tmp_path, short_clip):
    """R < N: a copy sitting on a child outside the key's placement set
    (ring change, delete racing a straggler) is pruned, and the
    legitimate replicas are untouched."""
    from repro.core.store import VSS

    vss = VSS(str(tmp_path / "vss"), backend="replicated:4")  # R=3 of 4
    try:
        vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
        vss.backend.quiesce()
        key = _gop_keys(vss, "v")[0]
        stray = next(
            ci for ci in range(4)
            if ci not in vss.backend.replicas_for(key)
        )
        vss.backend.replica_put(stray, key, vss.backend.get(key))
        report = vss.scrub()
        assert report.replicas_pruned == 1
        assert not vss.backend.children[stray].exists(key)
        assert vss.backend.replica_count(key) == 3
    finally:
        vss.close()


def test_tiered_over_replicated_scrub_reaches_the_replicas(tmp_path,
                                                           short_clip):
    """`tiered:replicated` is env-selectable; scrub/recover must reach
    THROUGH the hot tier to the replica layer — a generic scavenge
    probing via the wrapper would be satisfied by read-fallback and
    never notice a lost replica."""
    from repro.core.store import VSS

    vss = VSS(str(tmp_path / "vss"), backend="tiered:replicated:3")
    try:
        vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
        cold = vss.backend.cold
        cold.quiesce()
        key = _gop_keys(vss, "v")[0]
        victim = cold.replicas_for(key)[0]
        cold.replica_delete(victim, key)
        assert cold.replica_count(key) == 2
        report = vss.scrub()
        assert report.replicas_repaired == 1
        assert cold.replica_count(key) == 3
    finally:
        vss.close()


def test_online_scrub_never_collects_unreferenced_keys(rvss, short_clip):
    """Publishes are put-then-index, so to an ONLINE scrub a concurrent
    writer's freshly published window is indistinguishable from an
    orphan — the default scrub must leave unreferenced keys alone;
    collect_orphans=True (writes quiesced) collects them."""
    rvss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    rvss.backend.put("v/9/0.tvc", b"published-not-yet-indexed")
    rvss.backend.quiesce()
    report = rvss.scrub()
    assert report.orphans_removed == 0
    assert rvss.backend.exists("v/9/0.tvc")  # untouched
    report2 = rvss.scrub(collect_orphans=True)
    assert report2.orphans_removed == 1
    assert not rvss.backend.exists("v/9/0.tvc")


def test_replicated_store_reopens_under_same_layout(tmp_path, short_clip):
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="replicated:3")
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.close()
    with pytest.raises(ValueError, match="storage layout"):
        VSS(root, backend="replicated:4")
    vss2 = VSS(root, backend="replicated:3")
    try:
        assert vss2.read("v", codec="rgb", cache=False).frames.shape \
            == short_clip.shape
    finally:
        vss2.close()
