"""Per-kernel differential tests: Pallas (interpret mode) vs jnp oracle,
swept over shapes/dtypes (the kernel contract in kernels/ref.py)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def frames(t, c, h, w):
    return jnp.asarray(RNG.integers(0, 256, (t, c, h, w)).astype(np.float32))


@pytest.mark.parametrize("t,h,w", [
    (2, 8, 128), (6, 24, 200), (3, 17, 130), (5, 64, 256),
])
@pytest.mark.parametrize("q,lo,hi", [(2.0, -128, 127), (8.0, -128, 127),
                                     (1.0, -32768, 32767)])
def test_delta_codec_matches_oracle(t, h, w, q, lo, hi):
    x = frames(t, 3, h, w)
    ip, rp = ops.delta_encode(x, q=q, lo=lo, hi=hi, vmin=0, vmax=255,
                              use_pallas=True)
    ir, rr = ref.delta_encode(x, q=q, lo=lo, hi=hi, vmin=0, vmax=255)
    np.testing.assert_allclose(ip, ir, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(rp), np.asarray(rr))
    dp = ops.delta_decode(ip, rp.astype(jnp.int32), q=q, vmin=0, vmax=255,
                          use_pallas=True)
    dr = ref.delta_decode(ir, rr.astype(jnp.int32), q=q, vmin=0, vmax=255)
    np.testing.assert_allclose(dp, dr, atol=1e-4)


@pytest.mark.parametrize("factor", [1, 2, 4])
@pytest.mark.parametrize("q_in,q_out", [(2.0, 8.0), (8.0, 2.0)])
def test_transcode_fused_matches_oracle(factor, q_in, q_out):
    x = frames(4, 3, 32, 256)
    ifr, res = ref.delta_encode(x, q=q_in, lo=-128, hi=127, vmin=0, vmax=255)
    res = res.astype(jnp.int32)
    io_p, ro_p = ops.transcode(
        ifr, res, q_in=q_in, q_out=q_out, factor=factor, lo=-128, hi=127,
        vmin=0, vmax=255, use_pallas=True,
    )
    io_r, ro_r = ref.transcode(
        ifr, res, q_in=q_in, q_out=q_out, factor=factor, lo=-128, hi=127,
        vmin=0, vmax=255,
    )
    np.testing.assert_allclose(io_p, io_r, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(ro_p), np.asarray(ro_r))


@pytest.mark.parametrize("h,w,oh,ow", [
    (32, 160, 32, 160), (24, 136, 16, 128), (64, 256, 40, 200),
])
def test_warp_matches_oracle(h, w, oh, ow):
    img = frames(1, 3, h, w)[0]
    hmat = jnp.asarray(np.array(
        [[1.02, 0.03, 2.0], [0.01, 0.99, -1.5], [2e-5, 1e-5, 1.0]],
        np.float32,
    ))
    wp = ops.warp(img, hmat, out_shape=(oh, ow), use_pallas=True)
    wr = ref.warp(img, hmat, out_shape=(oh, ow))
    np.testing.assert_allclose(wp, wr, atol=5e-2)


@pytest.mark.parametrize("n,c,h,w,bins", [
    (1, 3, 16, 130, 16), (4, 3, 33, 128, 32), (2, 1, 8, 256, 8),
])
def test_histogram_matches_oracle(n, c, h, w, bins):
    x = frames(n, c, h, w)
    hp = ops.histogram(x, bins=bins, use_pallas=True)
    hr = ref.histogram(x, bins=bins)
    np.testing.assert_array_equal(np.asarray(hp), np.asarray(hr))
    assert int(hp.sum()) == n * c * h * w  # histograms partition pixels


@pytest.mark.parametrize("n,h,w", [(1, 8, 128), (4, 20, 150), (2, 64, 512)])
def test_mse_matches_oracle(n, h, w):
    a = frames(n, 1, h, w)[:, 0]
    b = a + jnp.asarray(RNG.normal(0, 5, a.shape).astype(np.float32))
    np.testing.assert_allclose(
        ops.mse_sum(a, b, use_pallas=True), ref.mse_sum(a, b), rtol=1e-5
    )


@pytest.mark.parametrize("b,hq,hkv,d,p,page", [
    (2, 4, 2, 64, 8, 16), (1, 8, 8, 128, 4, 8), (3, 8, 2, 128, 16, 32),
])
def test_paged_attention_matches_oracle(b, hq, hkv, d, p, page):
    q = jnp.asarray(RNG.standard_normal((b, hq, d)).astype(np.float32))
    kp = jnp.asarray(RNG.standard_normal((p, page, hkv, d)).astype(np.float32))
    vp = jnp.asarray(RNG.standard_normal((p, page, hkv, d)).astype(np.float32))
    maxp = p // 2
    bt = jnp.asarray(RNG.integers(0, p, (b, maxp)).astype(np.int32))
    sl = jnp.asarray(RNG.integers(1, maxp * page, (b,)).astype(np.int32))
    op = ops.paged_decode_attention(q, kp, vp, bt, sl, use_pallas=True)
    orf = ref.paged_decode_attention(q, kp, vp, bt, sl)
    np.testing.assert_allclose(op, orf, atol=1e-4)


def test_codec_roundtrip_through_gop_layer():
    """encode→serialize→deserialize→decode at the codec layer."""
    from repro import codec

    clip = RNG.integers(0, 256, (8, 24, 40, 3)).astype(np.uint8)
    for tier, tol in (("tvc-ll", 0), ("tvc-hi", 2), ("tvc-med", 6)):
        enc = codec.encode_gop(clip, tier)
        data = codec.serialize_gop(enc)
        dec = codec.decode_gop(codec.deserialize_gop(data))
        err = np.abs(dec.astype(int) - clip.astype(int)).max()
        assert err <= tol, f"{tier}: max err {err}"
