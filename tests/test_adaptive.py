"""Workload-adaptive format management (profile.py).

The contract under test, in order of importance:

1. **Observation is free**: with the policy off, a store that profiles
   its reads is bit-identical to one that doesn't — same frames, same
   plans, same fetch/decode counts.
2. The profile **persists** across close/reopen.
3. One ``adapt()`` tick drives the four seams: ahead-of-demand
   materialization, hot/cold tier placement, deferred-compression
   scheduling, and backpressure-driven ingest sizing.
"""
import os
import time

import numpy as np
import pytest

from repro.core import AdaptiveConfig, IngestConfig, VSSConfig
from repro.core.profile import suggest_ingest_sizing
from repro.core.store import VSS
from repro.obs import MetricsRegistry
from repro.storage import (
    FaultInjectingBackend,
    MemoryBackend,
    TieredBackend,
    unwrap,
)


def _store(tmp_path, name, **cfg_kw):
    cfg_kw.setdefault("registry", MetricsRegistry())
    return VSS(str(tmp_path / name), config=VSSConfig(**cfg_kw))


# ---------------------------------------------------------------------------
# 1. observation changes nothing
# ---------------------------------------------------------------------------

def _read_sequence(store):
    out = [store.read("v", codec="rgb", cache=False).frames]
    out.append(store.read("v", t=(0.5, 1.5), codec="tvc-med").frames)
    out.append(
        store.read("v", roi=(32, 16, 96, 80), codec="rgb", cache=False).frames
    )
    # replay of the cached view: planning must pick the same fragments
    out.append(
        store.read("v", t=(0.5, 1.5), codec="tvc-med", cache=False).frames
    )
    return out


def test_profiler_observation_is_bit_exact(tmp_path, clip):
    on = _store(tmp_path, "on",
                adaptive=AdaptiveConfig(profile=True, enabled=False))
    off = _store(tmp_path, "off", adaptive=AdaptiveConfig(profile=False))
    try:
        assert on.profiler is not None and on.adaptive is None
        assert off.profiler is None
        for s in (on, off):
            s.write("v", clip, fps=30.0, codec="tvc-hi")
        for a, b in zip(_read_sequence(on), _read_sequence(off)):
            assert np.array_equal(a, b)
        sa, sb = on.stats("v"), off.stats("v")
        for key in (
            "physical_videos", "gops", "bytes", "specs_read", "plan_groups",
            "specs_coalesced", "objects_fetched", "fetch_bytes",
            "gops_decoded",
        ):
            assert sa[key] == sb[key], key
    finally:
        on.close()
        off.close()


# ---------------------------------------------------------------------------
# 2. the profile survives a restart
# ---------------------------------------------------------------------------

def test_profile_persists_across_reopen(tmp_path, clip):
    root = str(tmp_path / "s")
    s = VSS(root, config=VSSConfig(registry=MetricsRegistry()))
    s.write("v", clip, fps=30.0, codec="tvc-hi")
    for _ in range(4):
        s.read("v", t=(0.0, 1.0), resolution=(64, 48), codec="rgb",
               cache=False)
    s.close()  # close() persists the profile
    assert os.path.exists(os.path.join(root, "profile.json"))

    s2 = VSS(root, config=VSSConfig(registry=MetricsRegistry()))
    try:
        hot = s2.profiler.hot_views("v", min_score=2.0)
        assert hot, "reopened store lost its learned view frequencies"
        (codec, _fps, _roi, res, _eps), score = hot[0]
        assert codec == "rgb" and tuple(res) == (64, 48)
        assert score >= 2.0
        assert s2.profiler.heat("v", 0.0, 1.0) >= 0.5
    finally:
        s2.close()


def test_drop_forgets_profile(tmp_path, clip):
    s = _store(tmp_path, "s")
    try:
        s.write("v", clip, fps=30.0, codec="tvc-hi")
        s.read("v", codec="rgb", cache=False)
        assert s.profiler.video_names() == ["v"]
        s.drop("v")
        assert s.profiler.video_names() == []
    finally:
        s.close()


# ---------------------------------------------------------------------------
# 3a. seam: ahead-of-demand materialization
# ---------------------------------------------------------------------------

def test_adapt_materializes_hot_view_ahead(tmp_path, clip):
    s = _store(tmp_path, "s", adaptive=AdaptiveConfig(enabled=True))
    try:
        s.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
        for _ in range(4):  # past min_view_score=3: this view is hot
            s.read("v", resolution=(64, 48), codec="tvc-med", cache=False)
        report = s.adapt()
        assert report["materialized"], "hot view was not materialized"
        derived = [
            p for p in s.catalog.physicals_for("v")
            if not p.is_original and p.codec == "tvc-med"
        ]
        assert derived, "no tvc-med physical exists after adapt()"
        # the whole extent is covered now: the next tick converges
        assert s.adapt()["materialized"] == []
        # and the next user read of that view is served from the
        # derived physical (pass-through), not transcoded
        r = s.read("v", resolution=(64, 48), codec="tvc-med", cache=False)
        chosen = {c.video_idx for c in r.plan.selection.chosen(r.plan.problem)}
        assert {r.plan.runs[i].physical.codec for i in chosen} == {"tvc-med"}
    finally:
        s.close()


# ---------------------------------------------------------------------------
# 3b. seam: tier placement by interval heat
# ---------------------------------------------------------------------------

def test_adapt_retiers_hot_and_cold_epochs(tmp_path, clip):
    tiered = TieredBackend(MemoryBackend(), hot_bytes=256 << 20)
    s = _store(
        tmp_path, "s", backend=tiered,
        adaptive=AdaptiveConfig(
            enabled=True, half_life_s=0.4, interval_s=0.5,
            min_view_score=1e9,  # isolate the tiering seam
        ),
    )
    try:
        s.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
        s.read("v", codec="rgb", cache=False)  # touch every epoch once
        time.sleep(1.8)                        # ... and let them go cold
        for _ in range(5):                     # epoch [0, 0.5) runs hot
            s.read("v", t=(0.0, 0.5), codec="rgb", cache=False)
        orig_id = s.catalog.get_original_id("v")
        path = {g.index: g.path for g in s.catalog.gops_for(orig_id)}

        report = s.adapt()
        hot_keys = set(unwrap(s.backend, TieredBackend).hot_keys())
        assert report["demoted"] > 0
        assert path[0] in hot_keys, "hot epoch was evicted from the hot tier"
        assert path[3] not in hot_keys, "cold epoch stayed resident"

        # the continuous seam: heat-boosted spill priority outranks LRU
        pf = s.adaptive.priority_fn(list(path.values()))
        assert pf[path[0]] > pf[path[3]]

        # promotion: drop everything, the next tick pulls hot epochs back
        tiered.demote(list(path.values()))
        report2 = s.adapt()
        assert report2["promoted"] > 0
        assert path[0] in set(tiered.hot_keys())
    finally:
        s.close()


# ---------------------------------------------------------------------------
# 3c. seam: deferred compression while ingest is idle
# ---------------------------------------------------------------------------

def test_adapt_schedules_deferred_compression(tmp_path, clip):
    s = _store(
        tmp_path, "s", budget_multiple=2.0,
        adaptive=AdaptiveConfig(enabled=True, min_view_score=1e9),
    )
    try:
        s.write("v", clip, fps=30.0, codec="rgb", gop_frames=15)
        assert s.deferred.active("v")
        report = s.adapt()
        assert report["deferred_steps"] > 0
        gops = s.catalog.gops_for(s.catalog.get_original_id("v"))
        assert any(g.zwrapped for g in gops)
    finally:
        s.close()


# ---------------------------------------------------------------------------
# 3d. seam: ingest auto-sizing
# ---------------------------------------------------------------------------

def test_suggest_ingest_sizing_scales_with_latency():
    class _CM:
        def __init__(self, latency_us):
            self.io_table = {"default": (latency_us, 0.0)}

    class _Backend:
        def kind_for(self, key):
            return "default"

    class _NoKind:
        def kind_for(self, key):
            raise RuntimeError("no kinds here")

    assert suggest_ingest_sizing(_CM(2e3), _Backend()) == (2, 32)
    assert suggest_ingest_sizing(_CM(5e4), _Backend()) == (4, 64)
    assert suggest_ingest_sizing(_CM(5e5), _Backend()) == (8, 128)
    # a backend without kinds falls back to the default io_table row
    assert suggest_ingest_sizing(_CM(2e3), _NoKind()) == (2, 32)


def test_backpressure_grows_ingest_pipeline(tmp_path):
    rng = np.random.default_rng(0)
    frames = rng.integers(0, 255, (120, 24, 32, 3), dtype=np.uint8)
    slow = FaultInjectingBackend(MemoryBackend(), seed=0, latency=0.02)
    s = _store(
        tmp_path, "s", backend=slow,
        ingest=IngestConfig(autosize=True),
        adaptive=AdaptiveConfig(enabled=True, min_view_score=1e9),
    )
    try:
        # construction already sized the pipeline from the io_table
        assert (s.ingest_workers, s.ingest_queue_gops) == \
            suggest_ingest_sizing(s.cost_model, slow)
        for i in range(3):  # tiny GOPs against a slow backend: the
            w = s.writer(f"v{i}", fps=30.0, codec="tvc-ll", gop_frames=2)
            w.append(frames)  # bounded queue must push back
            w.close()
            if s._ingest.stats().backpressure_waits > 0:
                break
        assert s._ingest.stats().backpressure_waits > 0
        before_w, before_q = (
            s._ingest.configured_workers, s._ingest.queue_gops)

        report = s.adapt()
        assert report["resized"] is not None
        assert s._ingest.configured_workers == min(16, before_w * 2)
        assert s._ingest.queue_gops == min(512, before_q * 2)
        assert s.ingest_workers == s._ingest.configured_workers

        # no new waits since the resize: the next tick is a no-op
        assert s.adapt()["resized"] is None

        # the grown pipeline still publishes correctly
        w = s.writer("after", fps=30.0, codec="tvc-ll", gop_frames=2)
        w.append(frames[:20])
        w.close()
        got = s.read("after", codec="rgb", cache=False).frames
        assert np.array_equal(got, frames[:20])
    finally:
        s.close()
