"""Training substrate: fault tolerance, checkpoints on VSS, data pipeline."""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.store import VSS
from repro.data.tokens import TokenPipeline, read_tokens, write_token_corpus
from repro.launch.steps import TrainHyper, init_train_state
from repro.train.checkpoint import (
    CheckpointManager,
    frames_to_tree,
    tree_to_frames,
)
from repro.train.runner import SimulatedFailure, Trainer, TrainerConfig

CFG = smoke_config("phi3-mini-3.8b")
HYPER = TrainHyper(num_microbatches=2, total_steps=40, warmup_steps=2)


@pytest.fixture()
def corpus(tmp_path):
    vss = VSS(str(tmp_path / "data"))
    tokens = np.random.default_rng(0).integers(
        0, CFG.vocab_size, 200_000
    ).astype(np.int32)
    n = write_token_corpus(vss, "corpus", tokens)
    yield vss, n, tokens
    vss.close()


def _trainer(tmp_path, corpus, sub, fail=None):
    vss, n, _ = corpus
    pipe = TokenPipeline(vss, "corpus", n, batch=4, seq=32)
    ck = CheckpointManager(str(tmp_path / f"ckpt_{sub}"), keep_last=2,
                           derived_reprs=("bf16",))
    return Trainer(CFG, HYPER, pipe, ck,
                   tcfg=TrainerConfig(checkpoint_every=4, fail_at_step=fail,
                                      log_every=4))


def test_pipeline_deterministic(corpus):
    vss, n, tokens = corpus
    p1 = TokenPipeline(vss, "corpus", n, batch=4, seq=32)
    p2 = TokenPipeline(vss, "corpus", n, batch=4, seq=32)
    b1 = p1.get(7)
    b2 = p2.get(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # step addressing is absolute: batch 7 == tokens at offset 7*4*33
    flat = read_tokens(vss, "corpus", 7 * 4 * 33, 4 * 33, n)
    np.testing.assert_array_equal(
        b1["tokens"], flat.reshape(4, 33)[:, :-1]
    )
    p1.close()
    p2.close()


def test_pipeline_straggler_bounded_staleness(corpus):
    vss, n, _ = corpus
    pipe = TokenPipeline(vss, "corpus", n, batch=2, seq=16,
                         deadline_s=0.05, delay_s=0.5)
    pipe.get(0)  # first fetch blocks hard (nothing staged)
    pipe.get(1)  # prefetched by get(0)'s tail prefetch... may or may not hit
    pipe.get(5)  # far fetch → deadline miss → stale reuse
    assert pipe.stats.stale_reuses >= 1
    pipe.close()


def test_checkpoint_roundtrip_and_retention(tmp_path):
    state = init_train_state(jax.random.key(0), CFG, HYPER)
    ck = CheckpointManager(str(tmp_path / "ck"), keep_last=2,
                           derived_reprs=("bf16", "int8"))
    for s in (4, 8, 12):
        ck.save(s, state, blocking=True)
    assert ck.steps() == [8, 12]  # keep_last=2 retention
    like = jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), CFG, HYPER)
    )
    restored, step = ck.restore(like=like)
    assert step == 12
    a = jax.tree_util.tree_leaves(state)
    b = jax.tree_util.tree_leaves(restored)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # quantized views restore approximately
    r8, _ = ck.restore(repr_="int8", like=like)
    for x, y in zip(a, jax.tree_util.tree_leaves(r8)):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        if x.size:
            assert np.abs(x - y).max() <= max(np.abs(x).max() / 100, 1e-6)
    ck.close()


def test_cold_checkpoints_deferred_compressed(tmp_path):
    state = init_train_state(jax.random.key(0), CFG, HYPER)
    ck = CheckpointManager(str(tmp_path / "ck"), keep_last=3)
    ck.save(1, state, blocking=True)
    ck.save(2, state, blocking=True)
    ck.save(3, state, blocking=True)
    sizes = {s: i.nbytes for s, i in ck.stats().items()}
    # cold masters (1, 2) are zstd-wrapped in place; newest stays raw
    assert sizes[1] < sizes[3]
    like = jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), CFG, HYPER)
    )
    restored, _ = ck.restore(step=1, like=like)  # wrapped GOPs still read
    for x, y in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    ck.close()


def test_crash_restart_bitwise_resume(tmp_path, corpus):
    t_ref = _trainer(tmp_path, corpus, "ref").init()
    t_ref.train(12)
    t1 = _trainer(tmp_path, corpus, "ft", fail=6).init()
    with pytest.raises(SimulatedFailure):
        t1.train(12)
    t1.ckpt.wait()  # durable storage finishes its in-flight write
    t2 = _trainer(tmp_path, corpus, "ft")
    assert t2.resume()
    assert t2.step == 4
    t2.train(12)
    for a, b in zip(jax.tree_util.tree_leaves(t_ref.state["params"]),
                    jax.tree_util.tree_leaves(t2.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_without_checkpoint_returns_false(tmp_path, corpus):
    t = _trainer(tmp_path, corpus, "none")
    assert not t.resume()
    t.init_or_resume()
    assert t.state is not None and t.step == 0


def test_tree_to_frames_roundtrip():
    tree = {"a": np.arange(13, dtype=np.float32),
            "b": {"c": np.ones((3, 5), np.int32)}}
    frames, spec = tree_to_frames(tree)
    assert frames.dtype == np.uint8 and frames.shape[1:] == (64, 128, 3)
    out = frames_to_tree(frames, spec, like=tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])
