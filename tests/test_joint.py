"""Joint compression (§5.1): Algorithm 1, recovery quality, candidates."""
import numpy as np

from repro.core.quality import exact_psnr
from repro.data.video import synthesize_overlapping_pair


def _write_pair(vss, left, right, gop=6):
    vss.write("cam_l", left, fps=30.0, codec="tvc-hi", gop_frames=gop)
    vss.write("cam_r", right, fps=30.0, codec="tvc-hi", gop_frames=gop)


def test_joint_compression_saves_storage_and_recovers(vss, overlap_pair):
    left, right, _ = overlap_pair
    _write_pair(vss, left, right)
    before = (vss.catalog.total_bytes("cam_l")
              + vss.catalog.total_bytes("cam_r"))
    jids = vss.apply_joint_compression(
        ["cam_l", "cam_r"], merge="mean", tau_db=24.0
    )
    assert jids, "no pair was jointly compressed"
    after = (vss.catalog.total_bytes("cam_l")
             + vss.catalog.total_bytes("cam_r"))
    assert after < before
    rl = vss.read("cam_l", codec="rgb", cache=False).frames
    rr = vss.read("cam_r", codec="rgb", cache=False).frames
    assert exact_psnr(rl, left) >= 24.0
    assert exact_psnr(rr, right) >= 24.0


def test_unprojected_merge_keeps_left_lossless(vss, overlap_pair):
    left, right, _ = overlap_pair
    _write_pair(vss, left, right)
    jids = vss.apply_joint_compression(
        ["cam_l", "cam_r"], merge="unprojected", tau_db=24.0
    )
    assert jids
    rl = vss.read("cam_l", codec="rgb", cache=False).frames
    rr = vss.read("cam_r", codec="rgb", cache=False).frames
    # Table 2: unprojected merge favors the left view
    assert exact_psnr(rl, left) >= exact_psnr(rr, right) - 1.0
    assert exact_psnr(rl, left) >= 30.0


def test_duplicate_frames_become_pointer(vss, clip):
    """§5.1.1: ‖H−I‖ ≤ ε → the redundant GOP is a pointer, not re-encoded."""
    vss.write("cam_a", clip[:12], fps=30.0, codec="tvc-hi", gop_frames=6)
    vss.write("cam_b", clip[:12].copy(), fps=30.0, codec="tvc-hi",
              gop_frames=6)
    jids = vss.apply_joint_compression(["cam_a", "cam_b"], merge="mean")
    assert jids
    rec = vss.catalog.get_joint(jids[0])
    assert rec["duplicate"]
    rb = vss.read("cam_b", codec="rgb", cache=False).frames
    assert exact_psnr(rb, clip[:12]) >= 40.0


def test_disjoint_videos_not_joined(vss):
    a = synthesize_overlapping_pair(6, width=96, height=64, seed=3)[0]
    b = synthesize_overlapping_pair(6, width=96, height=64, seed=99)[0]
    vss.write("cam_a", a, fps=30.0, codec="tvc-hi", gop_frames=6)
    vss.write("cam_b", b, fps=30.0, codec="tvc-hi", gop_frames=6)
    jids = vss.apply_joint_compression(["cam_a", "cam_b"], merge="mean",
                                       tau_db=24.0)
    # different worlds: either no candidates, or quality-verified abort
    for j in jids:
        rec = vss.catalog.get_joint(j)
        assert rec is not None  # any accepted pair must have verified ≥ τ
    ra = vss.read("cam_a", codec="rgb", cache=False).frames
    assert exact_psnr(ra, a) >= 24.0


def test_homography_estimation_accuracy(overlap_pair):
    from repro.core import features

    left, right, h_true = overlap_pair
    h_est = features.estimate_homography(left[0], right[0])
    assert h_est is not None
    # compare action on sample points rather than matrix entries
    pts = np.array([[10, 10, 1], [80, 40, 1], [30, 70, 1]], np.float32).T
    p_true = h_true @ pts
    p_est = h_est @ pts
    p_true /= p_true[2]
    p_est /= p_est[2]
    assert np.abs(p_true - p_est).max() < 3.0  # within 3 px
