"""Declarative ReadSpec/WriteSpec API, joint batch planning, writer
lifecycle, and the backend-aware I/O cost term."""
import os

import numpy as np
import pytest

from repro.core.cost import DEFAULT_IO_TABLE, CostModel, calibration_path
from repro.core.spec import ReadSpec, WriteSpec
from repro.core.store import VSS
from repro.storage import (
    LocalFSBackend,
    ShardedBackend,
    StorageBackend,
    TieredBackend,
)


class CountingBackend(StorageBackend):
    """Delegating wrapper that counts object fetches (one per ``get``,
    one per key in ``batch_get``) — the instrument behind the batched-
    read acceptance criterion."""

    def __init__(self, inner):
        self.inner = inner
        self.objects_fetched = 0
        self.batch_get_calls = 0
        self.get_calls = 0

    def reset(self):
        self.objects_fetched = 0
        self.batch_get_calls = 0
        self.get_calls = 0

    def put(self, key, data):
        self.inner.put(key, data)

    def batch_put(self, items):
        self.inner.batch_put(items)

    def get(self, key):
        self.get_calls += 1
        self.objects_fetched += 1
        return self.inner.get(key)

    def batch_get(self, keys):
        self.batch_get_calls += 1
        self.objects_fetched += len(keys)
        return self.inner.batch_get(keys)

    def delete(self, key):
        self.inner.delete(key)

    def stat(self, key):
        return self.inner.stat(key)

    def list(self, prefix=""):
        return self.inner.list(prefix)

    def sweep_temps(self):
        return self.inner.sweep_temps()

    def layout_fingerprint(self):
        return self.inner.layout_fingerprint()

    def kind_for(self, key):
        return self.inner.kind_for(key)

    def close(self):
        self.inner.close()


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

def test_unknown_codec_rejected():
    with pytest.raises(ValueError):
        ReadSpec(name="v", codec="vp9")
    with pytest.raises(ValueError):
        WriteSpec(name="v", codec="av1-maybe")


def test_codec_canonicalized_at_construction():
    assert ReadSpec(name="v", codec="H264").codec == "tvc-med"
    assert WriteSpec(name="v", codec="HEVC").codec == "tvc-hi"


def test_empty_or_malformed_interval_rejected():
    with pytest.raises(ValueError):
        ReadSpec(name="v", t=(2.0, 1.0))
    with pytest.raises(ValueError):
        ReadSpec(name="v", t=(1.0, 1.0))
    with pytest.raises(ValueError):
        ReadSpec(name="v", t=(0.0,))
    with pytest.raises(ValueError):
        ReadSpec(name="v", t=(0.0, float("nan")))


def test_degenerate_roi_rejected():
    for roi in [(10, 0, 5, 5), (0, 0, 0, 5), (-1, 0, 5, 5), (0, 0, 5)]:
        with pytest.raises(ValueError):
            ReadSpec(name="v", roi=roi)


def test_bad_resolution_fps_method_rejected():
    with pytest.raises(ValueError):
        ReadSpec(name="v", resolution=(0, 10))
    with pytest.raises(ValueError):
        ReadSpec(name="v", fps=-1.0)
    with pytest.raises(ValueError):
        ReadSpec(name="v", method="annealing")
    with pytest.raises(ValueError):
        WriteSpec(name="v", fps=0.0)
    with pytest.raises(ValueError):
        WriteSpec(name="v", gop_frames=0)
    with pytest.raises(ValueError):
        ReadSpec(name="")


def test_specs_are_immutable_and_hashable():
    spec = ReadSpec(name="v", t=(0.0, 1.0))
    with pytest.raises(Exception):
        spec.codec = "hevc"
    assert spec == ReadSpec(name="v", t=(0.0, 1.0))
    assert len({spec, ReadSpec(name="v", t=(0.0, 1.0))}) == 1


# ---------------------------------------------------------------------------
# resolve-time validation (against the stored original)
# ---------------------------------------------------------------------------

def test_out_of_range_interval_rejected(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    with pytest.raises(ValueError):
        vss.read_spec(ReadSpec(name="v", t=(1.0, 3.0)))
    with pytest.raises(ValueError):
        vss.read_spec(ReadSpec(name="v", t=(-0.5, 1.0)))


def test_roi_outside_frame_bounds_rejected(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")  # 128x96 frame
    with pytest.raises(ValueError):
        vss.read_spec(ReadSpec(name="v", roi=(0, 0, 500, 500)))


def test_unknown_video_raises_keyerror(vss):
    with pytest.raises(KeyError):
        vss.read_spec(ReadSpec(name="nope"))


# ---------------------------------------------------------------------------
# shim back-compat: keyword read() == spec path
# ---------------------------------------------------------------------------

def test_keyword_shim_matches_spec_path(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    kw = vss.read("v", t=(0.5, 1.5), codec="rgb", cache=False)
    sp = vss.read_spec(
        ReadSpec(name="v", t=(0.5, 1.5), codec="rgb", cache=False)
    )
    assert np.array_equal(kw.frames, sp.frames)
    assert kw.plan.segments == sp.plan.segments
    assert kw.plan.selection.assignment == sp.plan.selection.assignment


def test_keyword_shim_matches_spec_path_encoded(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    kw = vss.read("v", codec="hevc", cache=False)
    sp = vss.read_spec(ReadSpec(name="v", codec="hevc", cache=False))
    assert kw.nbytes == sp.nbytes
    assert np.array_equal(kw.frames, sp.frames)


# ---------------------------------------------------------------------------
# read_batch semantics
# ---------------------------------------------------------------------------

def test_read_batch_empty(vss):
    assert vss.read_batch([]) == []


def test_read_batch_rejects_non_specs(vss):
    with pytest.raises(TypeError):
        vss.read_batch(["v"])


def test_read_batch_matches_sequential(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
    specs = [
        ReadSpec(name="v", t=(0.0, 1.5), cache=False),
        ReadSpec(name="v", t=(0.5, 2.0), cache=False),
        ReadSpec(name="v", t=(1.0, 2.0), cache=False),
    ]
    seq = [vss.read_spec(s).frames for s in specs]
    batch = vss.read_batch(specs)
    assert len(batch) == len(specs)
    for got, want in zip(batch, seq):
        assert np.array_equal(got.frames, want)


def test_read_batch_duplicate_specs_independent_results(vss, clip):
    """Duplicates share one execution (see the fetch-count test) but the
    returned buffers stay independently mutable, as from sequential
    reads."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
    spec = ReadSpec(name="v", t=(0.0, 1.0), cache=False)
    a, b = vss.read_batch([spec, ReadSpec(name="v", t=(0.0, 1.0),
                                          cache=False)])
    assert a.frames is not b.frames
    assert np.array_equal(a.frames, b.frames)
    a.frames[:] = 0  # mutating one result must not corrupt the other
    assert not np.array_equal(a.frames, b.frames)
    ref = vss.read_spec(spec).frames
    assert np.array_equal(b.frames, ref)


def test_read_batch_subframe_interval_matches_sequential(vss, clip):
    """A sub-frame spec inside a larger batch must return the same
    frames as its sequential read, not a neighbouring segment's."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
    tiny = ReadSpec(name="v", t=(1.0, 1.01), cache=False)
    seq = vss.read_spec(tiny).frames
    _big, got = vss.read_batch([
        ReadSpec(name="v", t=(0.0, 1.0), cache=False), tiny,
    ])
    assert got.frames.shape == seq.shape
    assert np.array_equal(got.frames, seq)


def test_read_batch_joint_plan_demands(vss, clip):
    """Overlapping same-view specs share one joint problem; segments in
    the overlap carry demand > 1."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
    out = vss.read_batch([
        ReadSpec(name="v", t=(0.0, 1.5), cache=False),
        ReadSpec(name="v", t=(0.5, 2.0), cache=False),
    ])
    demands = [d for r in out for d in (r.plan.problem.demands or [])]
    assert demands and max(demands) == 2


def test_read_batch_across_videos(vss, clip):
    vss.write("a", clip, fps=30.0, codec="tvc-hi")
    vss.write("b", clip[:30], fps=30.0, codec="tvc-ll")
    ra, rb = vss.read_batch([
        ReadSpec(name="a", t=(0.0, 1.0), cache=False),
        ReadSpec(name="b", cache=False),
    ])
    assert ra.frames.shape[0] == 30
    assert np.array_equal(rb.frames, clip[:30])


def test_read_batch_mixed_configs_same_video(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
    r1, r2 = vss.read_batch([
        ReadSpec(name="v", t=(0.0, 2.0), codec="rgb", cache=False),
        ReadSpec(name="v", t=(0.0, 2.0), resolution=(64, 48),
                 codec="rgb", cache=False),
    ])
    assert r1.frames.shape[1:3] == (96, 128)
    assert r2.frames.shape[1:3] == (48, 64)


def test_read_batch_admissions_visible_after(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    before = vss.stats("v")["physical_videos"]
    vss.read_batch([
        ReadSpec(name="v", t=(0.0, 1.0), codec="tvc-med"),
        ReadSpec(name="v", t=(1.0, 2.0), codec="tvc-med"),
    ])
    assert vss.stats("v")["physical_videos"] > before


def test_read_batch_fewer_fetches_than_sequential(tmp_path, clip):
    """The acceptance criterion: N overlapping specs on ShardedBackend
    fetch strictly fewer objects through read_batch than N sequential
    read() calls, and each joint plan issues a single batch_get."""
    counting = CountingBackend(
        ShardedBackend.local(str(tmp_path / "objects"), 3)
    )
    vss = VSS(str(tmp_path / "vss"), backend=counting,
              enable_deferred=False, enable_compaction=False)
    try:
        vss.write("v", clip, fps=30.0, codec="tvc-ll", gop_frames=5)
        intervals = [(0.0, 1.5), (0.5, 2.0), (1.0, 2.0), (0.0, 1.5)]
        specs = [
            ReadSpec(name="v", t=t, cache=False) for t in intervals
        ]

        counting.reset()
        seq_frames = [
            vss.read("v", t=t, cache=False).frames for t in intervals
        ]
        seq_fetched = counting.objects_fetched

        counting.reset()
        batch = vss.read_batch(specs)
        batch_fetched = counting.objects_fetched

        assert batch_fetched < seq_fetched
        # one plan group (same view config) -> one batch_get for the union
        assert counting.batch_get_calls == 1
        assert counting.get_calls == 0
        # no key fetched twice within the batch
        assert batch_fetched <= 12  # 60 frames / 5-frame GOPs
        for got, want in zip(batch, seq_frames):
            assert np.array_equal(got.frames, want)
    finally:
        vss.close()


# ---------------------------------------------------------------------------
# priority hints (QoS)
# ---------------------------------------------------------------------------

def test_priority_validated_and_canonicalized():
    assert ReadSpec(name="v").priority == 0
    assert ReadSpec(name="v", priority=7).priority == 7
    assert ReadSpec(name="v", priority=-2).priority == -2
    assert ReadSpec(name="v", priority="3").priority == 3  # canonicalized
    with pytest.raises(ValueError):
        ReadSpec(name="v", priority="urgent")
    with pytest.raises(ValueError):
        ReadSpec(name="v", priority=None)


def test_priority_does_not_split_plan_groups(vss, clip):
    """Priority is an execution hint, not part of the view identity:
    same-view specs still share one joint problem."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
    out = vss.read_batch([
        ReadSpec(name="v", t=(0.0, 1.5), cache=False, priority=0),
        ReadSpec(name="v", t=(0.5, 2.0), cache=False, priority=9),
    ])
    demands = [d for r in out for d in (r.plan.problem.demands or [])]
    assert demands and max(demands) == 2  # still jointly planned


def test_read_batch_executes_by_priority_within_group(vss, clip,
                                                      monkeypatch):
    vss.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=15)
    order = []
    orig = VSS._execute

    def spy(self, plan, roi, resolution, out_fps, io=None):
        order.append((plan.segments[0][0], plan.segments[-1][1]))
        return orig(self, plan, roi, resolution, out_fps, io)

    monkeypatch.setattr(VSS, "_execute", spy)
    specs = [
        ReadSpec(name="v", t=(0.0, 0.5), cache=False, priority=0),
        ReadSpec(name="v", t=(0.5, 1.0), cache=False, priority=5),
        ReadSpec(name="v", t=(1.0, 1.5), cache=False, priority=2),
        ReadSpec(name="v", t=(1.5, 2.0), cache=False, priority=5),
    ]
    out = vss.read_batch(specs)
    # execution: priority 5 specs first (submission order breaks the
    # tie), then 2, then 0
    want = [(0.5, 1.0), (1.5, 2.0), (1.0, 1.5), (0.0, 0.5)]
    got_order = list(order)
    assert len(got_order) == len(want)
    for (ga, gb), (wa, wb) in zip(got_order, want):
        assert ga == pytest.approx(wa) and gb == pytest.approx(wb)
    # results stay order-preserving regardless of execution order
    seq = [vss.read_spec(sp).frames for sp in specs]
    for got, ref in zip(out, seq):
        assert np.array_equal(got.frames, ref)


# ---------------------------------------------------------------------------
# install-time calibration persistence
# ---------------------------------------------------------------------------

def test_calibrate_io_persists_and_loads_at_startup(tmp_path):
    root = str(tmp_path / "vss")
    vss = VSS(root, backend="memory")
    table = vss.calibrate_io(
        trials=1, small_bytes=1 << 10, large_bytes=1 << 16,
        reference_pixels_per_s=1e9,
    )
    assert "memory" in table
    assert os.path.exists(calibration_path(root))
    assert not vss.backend.list("_calib/")  # probe objects cleaned up
    saved = tuple(vss.cost_model.io_table["memory"])
    vss.close()

    vss2 = VSS(root, backend="memory")  # startup loads the saved model
    try:
        assert tuple(vss2.cost_model.io_table["memory"]) == \
            pytest.approx(saved)
        # kinds that were never measured fall back to the shipped table
        assert tuple(vss2.cost_model.io_table["remote"]) == \
            DEFAULT_IO_TABLE["remote"]
    finally:
        vss2.close()


def test_store_without_calibration_uses_defaults(tmp_path):
    vss = VSS(str(tmp_path / "vss"))
    try:
        assert vss.cost_model.io_table == DEFAULT_IO_TABLE
    finally:
        vss.close()


def test_torn_calibration_file_never_blocks_startup(tmp_path):
    """A crash mid-save (or hand-editing gone wrong) must not brick the
    store: an unreadable table warns and falls back to defaults."""
    root = str(tmp_path / "vss")
    os.makedirs(root, exist_ok=True)
    with open(calibration_path(root), "w") as f:
        f.write('{"alpha": {"rgb->rgb": [[100, ')  # torn JSON
    with pytest.warns(UserWarning, match="unreadable cost calibration"):
        vss = VSS(root)
    try:
        assert vss.cost_model.io_table == DEFAULT_IO_TABLE
    finally:
        vss.close()


# ---------------------------------------------------------------------------
# backend-aware I/O cost
# ---------------------------------------------------------------------------

def test_io_cost_orders_backend_kinds():
    cm = CostModel.default()
    n = 1 << 20
    assert cm.io_cost("memory", n) < cm.io_cost("localfs", n)
    assert cm.io_cost("localfs", n) < cm.io_cost("remote", n)
    assert cm.io_cost("unknown-kind", n) == cm.io_cost("default", n)


def test_cost_model_save_load_roundtrip(tmp_path):
    cm = CostModel.default()
    cm.io_table["remote"] = (123.0, 0.5)
    path = str(tmp_path / "cost.json")
    cm.save(path)
    loaded = CostModel.load(path)
    assert loaded.io_table["remote"] == (123.0, 0.5)
    assert loaded.alpha("rgb", "tvc-hi", 960 * 540) == pytest.approx(
        cm.alpha("rgb", "tvc-hi", 960 * 540)
    )


def test_tiered_kind_for_answers_per_key(tmp_path):
    tiered = TieredBackend(LocalFSBackend(str(tmp_path / "cold")),
                           hot_bytes=1 << 20)
    tiered.put("hot.bin", b"x" * 128)
    assert tiered.kind_for("hot.bin") == "memory"
    big = b"y" * (2 << 20)  # larger than the hot tier: cold only
    tiered.put("cold.bin", big)
    assert tiered.kind_for("cold.bin") == "localfs"
    tiered.close()


def test_plans_prefer_hot_tier_fragments(tmp_path, clip):
    """Two otherwise-identical candidate fragments on different tiers:
    the io_cost term must resolve the tie toward the faster one."""
    from repro.core.select import SegmentChoice, SelectionProblem, solve

    cm = CostModel.default()
    nbytes = 500_000
    base = 1000.0
    hot = SegmentChoice(0, base + cm.io_cost("memory", nbytes), 0.0)
    cold = SegmentChoice(1, base + cm.io_cost("localfs", nbytes), 0.0)
    sel = solve(SelectionProblem([(0.0, 1.0)], [[cold, hot]]), "dp")
    assert sel.assignment == [1]  # the memory-tier copy wins


# ---------------------------------------------------------------------------
# writer lifecycle (orphaned-logical fix) + batched publish
# ---------------------------------------------------------------------------

def test_abandoned_writer_leaves_nothing(vss, clip):
    w = vss.writer("x", fps=30.0, codec="tvc-hi")
    del w  # never appended, never closed
    assert not vss.catalog.logical_exists("x")
    # the name is immediately reusable
    vss.write("x", clip[:15], fps=30.0, codec="tvc-hi")
    assert vss.read("x", cache=False).frames.shape[0] == 15


def test_writer_registers_on_first_flush(vss, clip):
    w = vss.writer("y", fps=30.0, codec="tvc-hi", gop_frames=15)
    assert not vss.catalog.logical_exists("y")
    w.append(clip[:30])
    assert vss.catalog.logical_exists("y")
    w.close()


def test_writer_race_loses_at_first_flush(vss, clip):
    wa = vss.writer("z", fps=30.0, codec="tvc-hi", gop_frames=15)
    wb = vss.writer("z", fps=30.0, codec="tvc-hi", gop_frames=15)
    wa.append(clip[:15])
    with pytest.raises(ValueError):
        wb.append(clip[:15])


def test_writer_close_without_frames_raises(vss):
    w = vss.writer("w0", fps=30.0)
    with pytest.raises(ValueError):
        w.close()
    assert not vss.catalog.logical_exists("w0")


def test_recovery_drops_empty_logical(tmp_path):
    root = str(tmp_path / "vss")
    vss = VSS(root)
    vss.catalog.create_logical("ghost", 0)  # pre-flush crash turd
    vss.catalog.close()  # crash: no clean_shutdown marker
    vss.backend.close()
    reopened = VSS(root)
    try:
        assert not reopened.catalog.logical_exists("ghost")
    finally:
        reopened.close()


def test_writer_batch_gops_publishes_in_windows(vss, clip):
    from repro.core.spec import WriteSpec

    w = vss.writer_spec(
        WriteSpec(name="bw", fps=30.0, codec="tvc-hi", gop_frames=10),
        batch_gops=4,
    )
    w.append(clip[:30])  # 3 full GOPs buffered, below the window
    assert vss.stats("bw")["gops"] == 0
    w.append(clip[30:50])  # 5th GOP crosses the window -> publish
    assert vss.stats("bw")["gops"] >= 4
    w.append(clip[50:])
    w.close()
    assert vss.stats("bw")["gops"] == 6
    out = vss.read("bw", cache=False).frames
    assert np.array_equal(out, vss.read("bw", cache=False).frames)
    assert out.shape[0] == 60
