"""Integration tests for the VSS storage manager (paper §2–§3 behaviour)."""
import numpy as np
import pytest

from repro.core.quality import exact_psnr


def test_write_read_roundtrip_lossless(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-ll")
    out = vss.read("v", codec="rgb", cache=False).frames
    assert out.shape == clip.shape
    assert np.array_equal(out, clip)  # tvc-ll is bit-exact


@pytest.mark.parametrize("codec,min_db", [
    ("tvc-hi", 48.0), ("tvc-med", 38.0), ("tvc-lo", 28.0),
])
def test_tier_quality(vss, clip, codec, min_db):
    vss.write("v", clip, fps=30.0, codec=codec)
    out = vss.read("v", codec="rgb", cache=False).frames
    assert exact_psnr(out, clip) >= min_db


def test_temporal_range_read(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    r = vss.read("v", t=(0.5, 1.5), codec="rgb", cache=False)
    assert r.frames.shape[0] == 30
    ref = vss.read("v", codec="rgb", cache=False).frames[15:45]
    assert np.array_equal(r.frames, ref)


def test_roi_and_resolution(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    r = vss.read("v", roi=(32, 16, 96, 80), codec="rgb", cache=False)
    assert r.frames.shape[1:3] == (64, 64)
    r2 = vss.read("v", resolution=(64, 48), codec="rgb", cache=False)
    assert r2.frames.shape[1:3] == (48, 64)


def test_fps_division(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    r = vss.read("v", fps=15.0, codec="rgb", cache=False)
    assert r.frames.shape[0] == 30
    with pytest.raises(RuntimeError):
        vss.read("v", fps=45.0, codec="rgb", cache=False)  # non-integer ratio


def test_read_outside_interval_rejected(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    with pytest.raises(ValueError):
        vss.read("v", t=(1.0, 3.0), codec="rgb")


def test_no_overwrite_policy(vss, clip):
    vss.write("v", clip, fps=30.0)
    with pytest.raises(ValueError):
        vss.write("v", clip, fps=30.0)


def test_streaming_prefix_read(vss, clip):
    w = vss.writer("v", fps=30.0, codec="tvc-hi", gop_frames=15)
    w.append(clip[:30])  # two GOPs land
    r = vss.read("v", t=(0.0, 1.0), codec="rgb", cache=False)
    assert r.frames.shape[0] == 30
    w.append(clip[30:])
    w.close()
    r = vss.read("v", codec="rgb", cache=False)
    assert r.frames.shape[0] == 60


def test_cached_views_speed_up_plans(vss, clip):
    """After a cached read, later overlapping reads select cached fragments."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    r1 = vss.read("v", t=(0.5, 1.5), codec="tvc-med")  # cached as a view
    assert vss.stats("v")["physical_videos"] >= 2
    r2 = vss.read("v", t=(0.5, 1.5), codec="tvc-med", cache=False)
    # the cached tvc-med view should be chosen (same-codec fragments are
    # cheaper than transcoding the tvc-hi original)
    chosen = {c.video_idx for c in r2.plan.selection.chosen(r2.plan.problem)}
    codecs = {r2.plan.runs[i].physical.codec for i in chosen}
    assert "tvc-med" in codecs


def test_format_flexibility_any_to_any(vss, clip):
    vss.write("v", clip, fps=30.0, codec="h264")  # alias → tvc-med
    for out_codec in ("rgb", "hevc", "tvc-lo", "h264"):
        r = vss.read("v", codec=out_codec, cache=False,
                     quality_eps_db=20.0)
        assert r.frames.shape == clip.shape


def test_quality_cutoff_rejects_lossy_cache(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-ll")
    vss.read("v", codec="tvc-lo")  # caches a low-quality view
    r = vss.read("v", codec="rgb", quality_eps_db=45.0, cache=False)
    chosen = {c.video_idx for c in r.plan.selection.chosen(r.plan.problem)}
    for i in chosen:  # strict cutoff must avoid the tvc-lo view
        assert r.plan.runs[i].physical.codec != "tvc-lo"


def test_budget_eviction_keeps_lossless_cover(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi",
              budget_bytes=6_000_000)
    for t0 in (0.0, 0.5, 1.0):
        vss.read("v", t=(t0, t0 + 1.0), codec="rgb")  # big raw views
    # budget enforced (at least nothing unbounded) and a lossless cover
    # still reproduces the original
    out = vss.read("v", codec="rgb", cache=False).frames
    assert out.shape == clip.shape
    assert exact_psnr(out, clip) >= 40.0


# ---------------------------------------------------------------------------
# sub-GOP ranged reads + tiled physical layout
# ---------------------------------------------------------------------------

class _CountingBackend:
    """Wraps a backend and counts every payload byte it serves."""

    def __init__(self, inner):
        self._inner = inner
        self.bytes_served = 0

    def get(self, key):
        data = self._inner.get(key)
        self.bytes_served += len(data)
        return data

    def get_range(self, key, start, length):
        data = self._inner.get_range(key, start, length)
        self.bytes_served += len(data)
        return data

    def batch_get(self, keys):
        out = self._inner.batch_get(keys)
        self.bytes_served += sum(len(d) for d in out)
        return out

    def batch_get_ranges(self, reqs):
        out = self._inner.batch_get_ranges(reqs)
        self.bytes_served += sum(len(d) for d in out)
        return out

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_edge_trim_moves_strictly_fewer_bytes(tmp_path, clip):
    """A 3-frame read of a 30-frame GOP must fetch a strict byte subset
    of the GOP object — the ranged-I/O tentpole's core guarantee — and
    still decode bit-exactly."""
    from repro.core.store import VSS
    from repro.storage import MemoryBackend

    backend = _CountingBackend(MemoryBackend())
    vss = VSS(str(tmp_path / "vss"), backend=backend)
    vss.write("v", clip, fps=30.0, codec="tvc-ll", gop_frames=30)

    backend.bytes_served = 0
    trimmed = vss.read("v", t=(0.0, 3 / 30), codec="rgb", cache=False).frames
    trim_bytes = backend.bytes_served

    backend.bytes_served = 0
    full = vss.read("v", t=(0.0, 1.0), codec="rgb", cache=False).frames
    full_bytes = backend.bytes_served

    assert np.array_equal(trimmed, full[:3])  # bit-exact prefix decode
    assert trim_bytes < full_bytes  # strictly fewer bytes moved
    # the acceptance gate: a 3/30 trim keeps well under 60% of the bytes
    assert trim_bytes <= 0.6 * full_bytes
    assert vss.registry.value("vss_read_ranged_bytes_saved_total") > 0
    vss.close()


def test_tiled_roi_fetches_only_covering_tiles(tmp_path, clip):
    """An ROI read of a tiled video fetches a strict subset of the tile
    objects and stitches them bit-exactly."""
    from repro.core.spec import WriteSpec
    from repro.core.store import VSS
    from repro.storage import MemoryBackend

    backend = _CountingBackend(MemoryBackend())
    vss = VSS(str(tmp_path / "vss"), backend=backend)
    w = vss.writer_spec(WriteSpec(name="v", fps=30.0, codec="tvc-ll",
                                  gop_frames=15, tiles=(2, 2)))
    w.append(clip)
    w.close()

    backend.bytes_served = 0
    full = vss.read("v", codec="rgb", cache=False).frames
    full_bytes = backend.bytes_served
    assert np.array_equal(full, clip)  # lossless stitch of all tiles

    # a quadrant ROI needs 1 of 4 tiles per GOP
    h, w_, roi = clip.shape[1], clip.shape[2], (0, 0, 40, 30)
    backend.bytes_served = 0
    part = vss.read("v", roi=roi, codec="rgb", cache=False).frames
    assert np.array_equal(part, clip[:, :30, :40])
    assert backend.bytes_served < 0.5 * full_bytes
    assert vss.registry.value("vss_tile_fetches_total") > 0
    vss.close()


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    def _subgop_cases(fn):
        return settings(max_examples=12, deadline=None)(given(
            seed=st.integers(0, 2**31 - 1),
            codec=st.sampled_from(["tvc-ll", "tvc-hi", "tvc-med"]),
            hi=st.integers(1, 9),
            tiles=st.sampled_from([None, (2, 2), (1, 3), (3, 2)]),
        )(fn))

except ImportError:
    def _subgop_cases(fn):
        return pytest.mark.parametrize("seed,codec,hi,tiles", [
            (0, "tvc-ll", 3, None),
            (1, "tvc-hi", 1, (2, 2)),
            (2, "tvc-med", 7, (1, 3)),
            (3, "tvc-ll", 9, (3, 2)),
            (4, "tvc-hi", 4, None),
        ])(fn)


@_subgop_cases
def test_subgop_and_tile_bitexact_property(tmp_path_factory, seed, codec,
                                           hi, tiles):
    """Property: for any codec tier, trim point and tile grid, a ranged
    sub-GOP read and a tiled ROI read reproduce exactly the frames the
    whole-object path produces."""
    from repro.core.spec import WriteSpec
    from repro.core.store import VSS

    rng = np.random.default_rng(seed)
    base = rng.integers(0, 256, (1, 48, 64, 3), np.int16)
    drift = rng.integers(-2, 3, (24, 48, 64, 3), np.int16).cumsum(0)
    frames = np.clip(base + drift, 0, 255).astype(np.uint8)

    root = tmp_path_factory.mktemp("subgop")
    vss = VSS(str(root / "vss"))
    w = vss.writer_spec(WriteSpec(name="v", fps=12.0, codec=codec,
                                  gop_frames=12, tiles=tiles))
    w.append(frames)
    w.close()

    whole = vss.read("v", codec="rgb", cache=False).frames
    part = vss.read("v", t=(0.0, hi / 12.0), codec="rgb",
                    cache=False).frames
    assert np.array_equal(part, whole[:hi])

    roi = (tuple(rng.integers(0, 16, 2)) +
           tuple(rng.integers(33, 48, 1)) + tuple(rng.integers(33, 48, 1)))
    roi = (int(roi[0]), int(roi[1]), int(roi[2]), int(roi[3]))
    r = vss.read("v", roi=roi, codec="rgb", cache=False).frames
    assert np.array_equal(
        r, whole[:, roi[1]:roi[3], roi[0]:roi[2]]
    )
    vss.close()
