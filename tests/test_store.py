"""Integration tests for the VSS storage manager (paper §2–§3 behaviour)."""
import numpy as np
import pytest

from repro.core.quality import exact_psnr


def test_write_read_roundtrip_lossless(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-ll")
    out = vss.read("v", codec="rgb", cache=False).frames
    assert out.shape == clip.shape
    assert np.array_equal(out, clip)  # tvc-ll is bit-exact


@pytest.mark.parametrize("codec,min_db", [
    ("tvc-hi", 48.0), ("tvc-med", 38.0), ("tvc-lo", 28.0),
])
def test_tier_quality(vss, clip, codec, min_db):
    vss.write("v", clip, fps=30.0, codec=codec)
    out = vss.read("v", codec="rgb", cache=False).frames
    assert exact_psnr(out, clip) >= min_db


def test_temporal_range_read(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    r = vss.read("v", t=(0.5, 1.5), codec="rgb", cache=False)
    assert r.frames.shape[0] == 30
    ref = vss.read("v", codec="rgb", cache=False).frames[15:45]
    assert np.array_equal(r.frames, ref)


def test_roi_and_resolution(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    r = vss.read("v", roi=(32, 16, 96, 80), codec="rgb", cache=False)
    assert r.frames.shape[1:3] == (64, 64)
    r2 = vss.read("v", resolution=(64, 48), codec="rgb", cache=False)
    assert r2.frames.shape[1:3] == (48, 64)


def test_fps_division(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    r = vss.read("v", fps=15.0, codec="rgb", cache=False)
    assert r.frames.shape[0] == 30
    with pytest.raises(RuntimeError):
        vss.read("v", fps=45.0, codec="rgb", cache=False)  # non-integer ratio


def test_read_outside_interval_rejected(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    with pytest.raises(ValueError):
        vss.read("v", t=(1.0, 3.0), codec="rgb")


def test_no_overwrite_policy(vss, clip):
    vss.write("v", clip, fps=30.0)
    with pytest.raises(ValueError):
        vss.write("v", clip, fps=30.0)


def test_streaming_prefix_read(vss, clip):
    w = vss.writer("v", fps=30.0, codec="tvc-hi", gop_frames=15)
    w.append(clip[:30])  # two GOPs land
    r = vss.read("v", t=(0.0, 1.0), codec="rgb", cache=False)
    assert r.frames.shape[0] == 30
    w.append(clip[30:])
    w.close()
    r = vss.read("v", codec="rgb", cache=False)
    assert r.frames.shape[0] == 60


def test_cached_views_speed_up_plans(vss, clip):
    """After a cached read, later overlapping reads select cached fragments."""
    vss.write("v", clip, fps=30.0, codec="tvc-hi")
    r1 = vss.read("v", t=(0.5, 1.5), codec="tvc-med")  # cached as a view
    assert vss.stats("v")["physical_videos"] >= 2
    r2 = vss.read("v", t=(0.5, 1.5), codec="tvc-med", cache=False)
    # the cached tvc-med view should be chosen (same-codec fragments are
    # cheaper than transcoding the tvc-hi original)
    chosen = {c.video_idx for c in r2.plan.selection.chosen(r2.plan.problem)}
    codecs = {r2.plan.runs[i].physical.codec for i in chosen}
    assert "tvc-med" in codecs


def test_format_flexibility_any_to_any(vss, clip):
    vss.write("v", clip, fps=30.0, codec="h264")  # alias → tvc-med
    for out_codec in ("rgb", "hevc", "tvc-lo", "h264"):
        r = vss.read("v", codec=out_codec, cache=False,
                     quality_eps_db=20.0)
        assert r.frames.shape == clip.shape


def test_quality_cutoff_rejects_lossy_cache(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-ll")
    vss.read("v", codec="tvc-lo")  # caches a low-quality view
    r = vss.read("v", codec="rgb", quality_eps_db=45.0, cache=False)
    chosen = {c.video_idx for c in r.plan.selection.chosen(r.plan.problem)}
    for i in chosen:  # strict cutoff must avoid the tvc-lo view
        assert r.plan.runs[i].physical.codec != "tvc-lo"


def test_budget_eviction_keeps_lossless_cover(vss, clip):
    vss.write("v", clip, fps=30.0, codec="tvc-hi",
              budget_bytes=6_000_000)
    for t0 in (0.0, 0.5, 1.0):
        vss.read("v", t=(t0, t0 + 1.0), codec="rgb")  # big raw views
    # budget enforced (at least nothing unbounded) and a lossless cover
    # still reproduces the original
    out = vss.read("v", codec="rgb", cache=False).frames
    assert out.shape == clip.shape
    assert exact_psnr(out, clip) >= 40.0
