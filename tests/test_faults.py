"""Chaos suite: `FaultInjectingBackend` driving the remote retry path,
replicated quorum/fallback, and the §2 pipeline under injected faults.

The wrapper is the shared fault fixture for the whole backend matrix
(see also its quiet run inside test_storage.py's conformance suite):
seeded, so every failing sequence replays bit-identically."""
import threading
import time

import numpy as np
import pytest

from repro.storage import (
    FaultInjectingBackend,
    InjectedFault,
    LocalFSBackend,
    MemoryBackend,
    ObjectServer,
    RemoteBackend,
    RemoteError,
    ReplicatedBackend,
)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def _chaos_trace(seed):
    b = FaultInjectingBackend(MemoryBackend(), seed=seed, error_rate=0.35,
                              torn_write_rate=0.2)
    outcomes = []
    for i in range(40):
        try:
            if i % 3 == 0:
                b.put(f"k{i % 7}", b"payload" * 10)
            elif i % 3 == 1:
                b.get(f"k{(i - 1) % 7}")
            else:
                b.stat(f"k{(i - 2) % 7}")
            outcomes.append("ok")
        except (InjectedFault, Exception) as exc:
            outcomes.append(type(exc).__name__)
    return outcomes, list(b.fault_log)


def test_seeded_chaos_is_reproducible():
    """Same seed, same op sequence -> identical faults, outcomes and
    fault log; a different seed produces different weather."""
    a_out, a_log = _chaos_trace(42)
    b_out, b_log = _chaos_trace(42)
    assert a_out == b_out and a_log == b_log
    c_out, c_log = _chaos_trace(43)
    assert (a_out, a_log) != (c_out, c_log)


def test_fail_next_forces_exact_failures():
    b = FaultInjectingBackend(MemoryBackend(), seed=0)
    b.put("k", b"v")
    b.fail_next(2)
    with pytest.raises(InjectedFault):
        b.get("k")
    with pytest.raises(InjectedFault):
        b.get("k")
    assert b.get("k") == b"v"  # exactly two, then clean
    assert b.injected_errors == 2


def test_wrapper_is_transparent_to_calibration():
    """Calibrating through the wrapper must price the wrapped store's
    real kind, not file weather under the wrapper's default."""
    inner = MemoryBackend()
    b = FaultInjectingBackend(inner, seed=0)
    assert b.calibration_targets() == {"memory": inner}


def test_hang_then_recover():
    b = FaultInjectingBackend(MemoryBackend(), seed=0)
    b.put("k", b"v")
    b.hang()
    got = []
    t = threading.Thread(target=lambda: got.append(b.get("k")))
    t.start()
    t.join(timeout=0.2)
    assert t.is_alive() and not got  # stalled, not failed
    b.resume()
    t.join(timeout=30.0)
    assert got == [b"v"]


# ---------------------------------------------------------------------------
# remote retry path under server-side faults
# ---------------------------------------------------------------------------

@pytest.fixture()
def flaky_served():
    store = FaultInjectingBackend(MemoryBackend(), seed=3)
    server = ObjectServer(store)
    rb = RemoteBackend(server.url, max_retries=3, backoff_base=0.005)
    yield store, rb
    store.resume()
    rb.close()
    server.close()


def test_remote_retries_ride_out_transient_5xx(flaky_served):
    store, rb = flaky_served
    rb.put("k", b"v")
    store.fail_next(2)  # two 500s, then the third attempt lands
    assert rb.get("k") == b"v"
    assert rb.retries == 2


def test_remote_retries_exhaust_then_raise(flaky_served):
    store, rb = flaky_served
    rb.put("k", b"v")
    store.fail_next(10 ** 6)  # never recovers within the budget
    before = rb.retries
    with pytest.raises(RemoteError, match="failed after 4 attempts"):
        rb.get("k")
    assert rb.retries - before == 3  # max_retries, no unbounded spin
    store.fail_next(0)


def test_remote_put_survives_faulty_commit_path(flaky_served):
    """Faults striking inside the server-side rename (get/put/delete on
    the backing store) answer 500; the client's retried POST must land
    the commit exactly once, with no temp debris."""
    from repro.storage.remote import TEMP_PREFIX

    store, rb = flaky_served
    store.fail_next(1)  # the first backing-store op of the put 500s
    rb.put("k", b"exactly-once")
    assert rb.retries >= 1
    assert store.inner.get("k") == b"exactly-once"
    rb.sweep_temps()
    assert all(not k.startswith(TEMP_PREFIX) for k in store.inner.list())


def test_remote_rides_out_hang_then_recover(flaky_served):
    store, rb = flaky_served
    rb.put("k", b"v")
    store.hang()
    got = []
    t = threading.Thread(target=lambda: got.append(rb.get("k")))
    t.start()
    t.join(timeout=0.2)
    assert t.is_alive()  # blocked on the hung device, not erroring
    store.resume()
    t.join(timeout=30.0)
    assert got == [b"v"]


# ---------------------------------------------------------------------------
# replicated quorum under injected faults
# ---------------------------------------------------------------------------

def _replicated_with_faulty_child(tmp_path, **fault_kw):
    children = [
        FaultInjectingBackend(
            LocalFSBackend(str(tmp_path / "c0")), seed=11, **fault_kw
        ),
        LocalFSBackend(str(tmp_path / "c1")),
        LocalFSBackend(str(tmp_path / "c2")),
    ]
    return children[0], ReplicatedBackend(
        children,
        # corruption detection for the raw test payloads: a complete
        # object carries its full declared length
        validate=lambda d: len(d) >= 64,
    )


def test_quorum_writes_survive_injected_torn_writes(tmp_path):
    """Every write to child 0 tears (truncated bytes land AND the put
    raises): quorum still reached on the healthy children, and reads
    never return the partially-written bytes."""
    faulty, rb = _replicated_with_faulty_child(
        tmp_path, torn_write_rate=1.0
    )
    keys = [f"v/{i}/0.tvc" for i in range(12)]
    full = {k: k.encode() * 8 for k in keys}  # >= 64 bytes each
    for k in keys:
        rb.put(k, full[k])
    rb.quiesce()
    torn_keys = [
        k for k in keys
        if 0 in rb.replicas_for(k) and faulty.inner.exists(k)
    ]
    assert torn_keys  # the faulty child really holds torn objects
    assert all(
        len(faulty.inner.get(k)) < len(full[k]) for k in torn_keys
    )
    for k in keys:  # reads skip the torn copies via validate-fallback
        assert rb.get(k) == full[k]
    assert rb.batch_get(keys) == [full[k] for k in keys]
    assert rb.stats.degraded_writes > 0
    rb.close()


def test_transient_child_faults_never_fail_quorum_ops(tmp_path):
    faulty, rb = _replicated_with_faulty_child(tmp_path, error_rate=0.4)
    keys = [f"v/{i}/0.tvc" for i in range(20)]
    full = {k: k.encode() * 8 for k in keys}
    rb.batch_put(list(full.items()))  # quorum met despite the weather
    rb.quiesce()
    assert rb.batch_get(keys) == [full[k] for k in keys]
    for k in keys:
        assert rb.get(k) == full[k]
    assert faulty.injected_errors > 0  # the chaos actually fired
    rb.close()


def test_vss_pipeline_survives_flaky_replica(tmp_path):
    """End-to-end §2 chaos: one of three replicas randomly failing and
    tearing writes, and the full write -> cached read -> recode path
    still returns exact frames."""
    from repro.core.store import VSS
    from repro.data.video import synthesize_road
    from repro.storage import validate_gop_bytes

    clip = synthesize_road(30, width=128, height=96, seed=5)
    children = [
        FaultInjectingBackend(
            LocalFSBackend(str(tmp_path / "c0")), seed=9,
            error_rate=0.25, torn_write_rate=0.25,
        ),
        LocalFSBackend(str(tmp_path / "c1")),
        LocalFSBackend(str(tmp_path / "c2")),
    ]
    backend = ReplicatedBackend(children, validate=validate_gop_bytes)
    vss = VSS(str(tmp_path / "vss"), backend=backend)
    try:
        vss.write("v", clip, fps=30.0, codec="tvc-ll", gop_frames=10)
        out = vss.read("v", codec="rgb", cache=False).frames
        assert np.array_equal(out, clip)  # tvc-ll: bit-exact or bust
        out2 = vss.read("v", t=(0.3, 0.9), codec="rgb", cache=False).frames
        assert np.array_equal(out2, clip[9:27])
    finally:
        vss.close()


def test_scrub_repairs_what_chaos_tore(tmp_path):
    """After a torn-write storm, the scrubber restores every replica
    from a healthy copy (the shared-repair path the remote sweep and
    replicated recovery both lean on)."""
    from repro.core.store import VSS
    from repro.data.video import synthesize_road
    from repro.storage import validate_gop_bytes

    clip = synthesize_road(30, width=128, height=96, seed=6)
    faulty = FaultInjectingBackend(
        LocalFSBackend(str(tmp_path / "c0")), seed=21, torn_write_rate=0.5,
    )
    children = [faulty,
                LocalFSBackend(str(tmp_path / "c1")),
                LocalFSBackend(str(tmp_path / "c2"))]
    backend = ReplicatedBackend(children, validate=validate_gop_bytes)
    vss = VSS(str(tmp_path / "vss"), backend=backend)
    try:
        vss.write("v", clip, fps=30.0, codec="tvc-med", gop_frames=10)
        backend.quiesce()
        assert faulty.injected_torn > 0  # the storm happened
        faulty.torn_write_rate = 0.0     # weather clears; now heal
        report = vss.scrub()
        assert report.replicas_repaired > 0
        keys = [g.path for g in vss.catalog.all_gops()
                if g.joint_ref is None]
        assert keys and all(
            backend.replica_count(k) == backend.replicas for k in keys
        )
        # every replica of every key now validates
        for k in keys:
            for ci in backend.replicas_for(k):
                assert validate_gop_bytes(backend.replica_get(ci, k))
    finally:
        vss.close()


# ---------------------------------------------------------------------------
# injected latency (the knob fig26 uses to emulate a WAN round trip)
# ---------------------------------------------------------------------------

def test_injected_latency_slows_ops_measurably():
    b = FaultInjectingBackend(MemoryBackend(), seed=0, latency=0.01)
    b.put("k", b"v")
    t0 = time.perf_counter()
    for _ in range(10):
        b.get("k")
    elapsed = time.perf_counter() - t0
    # mean delay 10ms/op, uniform on [0, 20ms]: 10 ops take >0 — use a
    # generous floor so slow CI can't flake it
    assert elapsed > 0.02
    b.close()
