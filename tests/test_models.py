"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + NaN assertions, prefill/decode parity with the parallel pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import model as M
from repro.models.sharding import ShardCtx

CTX = ShardCtx(None)
B, S = 2, 24


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.num_frontend_tokens, cfg.frontend_dim)
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.num_frontend_tokens, cfg.frontend_dim)
        )
    return batch


def _smoke_cfg(arch):
    cfg = smoke_config(arch)
    if cfg.moe is not None:
        # forward drops tokens at expert capacity; decode never does —
        # lift capacity so the parity check isolates real bugs
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = _smoke_cfg(arch)
    params = M.init_model(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    logits, aux = jax.jit(lambda p, b: M.forward(p, cfg, b, CTX))(
        params, batch
    )
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch, CTX))
    )(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(
        (g.astype(jnp.float32) ** 2).sum()
        for g in jax.tree_util.tree_leaves(grads)
    ))
    assert float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_parity(arch):
    cfg = _smoke_cfg(arch)
    params = M.init_model(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    logits, _ = jax.jit(lambda p, b: M.forward(p, cfg, b, CTX))(params, batch)
    cache = M.init_cache(cfg, B, max_len=S + 8)
    pre = dict(batch, tokens=batch["tokens"][:, : S - 1])
    lg_pre, cache = jax.jit(lambda p, b, c: M.prefill(p, cfg, b, c, CTX))(
        params, pre, cache
    )
    lg_dec, cache = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t, CTX))(
        params, cache, batch["tokens"][:, S - 1:]
    )
    full = np.asarray(logits, np.float32)
    np.testing.assert_allclose(
        np.asarray(lg_pre[:, 0], np.float32), full[:, -2], atol=0.35
    )
    dec = np.asarray(lg_dec[:, 0], np.float32)
    if cfg.moe is not None:
        # top-k routing is discontinuous: under decode-path bf16
        # rounding a knife-edge token (measured top-2 router gap 0.003
        # for llama4 at this seed) can legitimately flip experts, moving
        # that row's logits a lot. Require the bulk of logits to agree —
        # a genuinely broken decode path agrees on ~none of them.
        assert (np.abs(dec - full[:, -1]) < 0.35).mean() > 0.6
    else:
        np.testing.assert_allclose(dec, full[:, -1], atol=0.35)
    assert int(cache["pos"][0]) == S


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_numbers_match_brief(arch):
    """The full configs must carry the exact published dimensions."""
    expect = {
        "phi3_mini_3_8b": (32, 3072, 32, 32, 8192, 32064),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102400),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "llama_3_2_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect


def test_moe_specs():
    d = get_config("deepseek_moe_16b")
    assert (d.moe.num_experts, d.moe.top_k, d.moe.num_shared) == (64, 6, 2)
    l4 = get_config("llama4_scout_17b_a16e")
    assert (l4.moe.num_experts, l4.moe.top_k) == (16, 1)


def test_long_context_only_for_sub_quadratic():
    from repro.configs.base import shapes_for

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = {s.name for s in shapes_for(cfg)}
        if arch in ("recurrentgemma_2b", "xlstm_1_3b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names


def test_int8_kv_view_decode_parity():
    """§4 multi-representation cached views applied to KV: the int8 view
    must agree with full-precision decode on argmax and closely on
    logits (phi3 smoke)."""
    cfg = _smoke_cfg("phi3_mini_3_8b")
    params = M.init_model(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                cfg.vocab_size)
    logits, _ = jax.jit(lambda p, b: M.forward(p, cfg, b, CTX))(
        params, {"tokens": tokens}
    )
    cache = M.init_cache(cfg, B, max_len=S + 4, kv_int8=True)
    assert cache["groups"]["0_attn"]["k"].dtype == jnp.int8
    _, cache = jax.jit(lambda p, b, c: M.prefill(p, cfg, b, c, CTX))(
        params, {"tokens": tokens[:, : S - 1]}, cache
    )
    lgd, _ = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t, CTX))(
        params, cache, tokens[:, S - 1:]
    )
    ref = np.asarray(logits[:, -1], np.float32)
    got = np.asarray(lgd[:, 0], np.float32)
    assert np.abs(got - ref).max() < 0.5
    assert (got.argmax(-1) == ref.argmax(-1)).all()


def test_mlstm_prefill_state_matches_step_chain():
    """Closed-form prefill state == unrolled single-step recurrence."""
    from repro.models import recurrent as R

    cfg = R.MLstmCfg(d_model=32, num_heads=2)
    params = R.init_mlstm(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 12, 32), jnp.float32)
    _, state_par = R.mlstm_block_prefill(params, x, cfg, CTX)
    state_seq = R.mlstm_init_state(2, cfg, dtype=jnp.float32)
    for t in range(12):
        state_seq, _ = R.mlstm_block_step(params, state_seq, x[:, t], cfg, CTX)
    np.testing.assert_allclose(
        np.asarray(state_par["C"]), np.asarray(state_seq["C"]), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(state_par["m"]), np.asarray(state_seq["m"]), atol=1e-5
    )
