"""`RemoteBackend` + the bundled object server: wire protocol, retry
policy, the idempotency-safe temp-key put, and crash recovery.

Contract-level conformance (roundtrips, batches, atomicity, listing)
runs in test_storage.py's `TestBackendConformance` matrix; chaos-level
behaviour (retry exhaustion, torn writes, hangs) in test_faults.py.
This file covers what is specific to the HTTP seam."""
import ssl
import time
import urllib.error
import urllib.parse
import urllib.request

import numpy as np
import pytest

from repro.storage import (
    LocalFSBackend,
    MemoryBackend,
    ObjectNotFound,
    ObjectServer,
    RemoteAuthError,
    RemoteBackend,
    RemoteError,
    RequestSigner,
    TieredBackend,
)
from repro.storage.remote import TEMP_PREFIX, _Response


@pytest.fixture()
def served(tmp_path):
    """(server, backend) over a LocalFS store the test can reach
    behind the wire."""
    store = LocalFSBackend(str(tmp_path / "objects"))
    server = ObjectServer(store)
    rb = RemoteBackend(server.url, backoff_base=0.01)
    yield server, rb, store
    rb.close()
    server.close()


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------

def test_ranged_get_partial_object(served):
    _server, rb, _store = served
    rb.put("v/1/0.tvc", b"0123456789" * 10)
    assert rb.get_range("v/1/0.tvc", 0, 4) == b"0123"
    assert rb.get_range("v/1/0.tvc", 95, 5) == b"56789"
    assert rb.get_range("v/1/0.tvc", 10, 1000) == b"0123456789" * 9
    with pytest.raises(ObjectNotFound):
        rb.get_range("missing", 0, 4)
    with pytest.raises(ValueError):
        rb.get_range("v/1/0.tvc", 100, 4)  # start past the end
    with pytest.raises(ValueError):
        rb.get_range("v/1/0.tvc", -1, 4)


def test_ranged_get_slices_when_server_ignores_range(served):
    """An external server without Range support answers 200 + full
    body; the client must slice rather than hand back the whole
    object as if it were the requested window."""
    server, _rb, _store = served

    class NoRangeServer(RemoteBackend):
        def _request(self, method, path, body=None, headers=None):
            headers = {k: v for k, v in (headers or {}).items()
                       if k != "Range"}
            return super()._request(method, path, body=body,
                                    headers=headers)

    rb = NoRangeServer(server.url, backoff_base=0.01)
    try:
        rb.put("k", b"0123456789" * 10)
        assert rb.get_range("k", 6, 5) == b"67890"
        assert rb.get_range("k", 95, 100) == b"56789"
        with pytest.raises(ValueError):
            rb.get_range("k", 100, 4)
    finally:
        rb.close()


def test_server_speaks_plain_http(served):
    """Any HTTP client can read the store — the protocol is the
    commodity S3-shaped surface, not a private RPC."""
    server, rb, _store = served
    rb.put("plain/key.bin", b"wire-visible")
    with urllib.request.urlopen(f"{server.url}/o/plain/key.bin") as resp:
        assert resp.status == 200
        assert resp.read() == b"wire-visible"
    req = urllib.request.Request(
        f"{server.url}/o/plain/key.bin", headers={"Range": "bytes=5-11"}
    )
    with urllib.request.urlopen(req) as resp:
        assert resp.status == 206
        assert resp.read() == b"visible"


def test_keys_with_url_hostile_characters(served):
    _server, rb, _store = served
    # the %41 key is the double-decoding canary: a server that
    # URL-decodes twice would commit it as "v 1/aAb..." instead
    for key in ("v 1/ob+j&ect=#0.tvc", "v 1/a%41b?x=1.tvc"):
        rb.put(key, b"quoted")
        assert rb.get(key) == b"quoted"
        assert rb.stat(key).nbytes == 6
        assert key in rb.list("v 1/")
    assert sorted(rb.list("v 1/")) == sorted(
        ["v 1/ob+j&ect=#0.tvc", "v 1/a%41b?x=1.tvc"]
    )
    for key in ("v 1/ob+j&ect=#0.tvc", "v 1/a%41b?x=1.tvc"):
        rb.delete(key)
        assert not rb.exists(key)


def test_remote_rejects_escaping_keys(served):
    _server, rb, _store = served
    for bad in ("/abs", "../escape", "a/../../b"):
        with pytest.raises(ValueError):
            rb.put(bad, b"x")


def test_missing_key_is_miss_not_retry(served):
    """4xx answers are protocol, not weather: a plain miss must not
    burn the retry budget (or its backoff time)."""
    _server, rb, _store = served
    with pytest.raises(ObjectNotFound):
        rb.get("nope")
    with pytest.raises(ObjectNotFound):
        rb.stat("nope")
    assert rb.retries == 0


# ---------------------------------------------------------------------------
# idempotency-safe put (temp key + server-side rename)
# ---------------------------------------------------------------------------

def test_put_goes_through_temp_key_and_commit(served):
    """Uploads land under the reserved temp prefix and only the rename
    publishes — mid-upload state is invisible to readers and lists."""
    _server, rb, store = served
    rb.put("v/1/0.tvc", b"committed")
    # nothing left under the temp prefix after a successful put
    assert [k for k in store.list() if k.startswith(TEMP_PREFIX)] == []
    assert store.get("v/1/0.tvc") == b"committed"


def test_crashed_upload_leaves_temp_swept_at_recovery(served):
    """A client that died between upload and commit: the destination
    key is untouched, the turd is swept by startup recovery."""
    _server, rb, store = served
    rb.put("v/1/0.tvc", b"live")
    # simulate the crash: the upload half of put(), no rename
    rb._request("PUT", rb._opath(f"{TEMP_PREFIX}deadbeef-1-0"),
                body=b"never committed")
    assert rb.get("v/1/0.tvc") == b"live"
    assert all(not k.startswith(TEMP_PREFIX) for k in rb.list())
    assert rb.sweep_temps() == 1
    assert [k for k in store.list() if k.startswith(TEMP_PREFIX)] == []
    assert rb.get("v/1/0.tvc") == b"live"  # live keys untouched


def test_rename_retry_after_lost_ack_is_accepted(tmp_path):
    """The commit's 204 lost in transit: the retried rename sees 404
    (source already consumed) and must reconcile via the destination —
    exactly the committed bytes means the put succeeded."""
    store = MemoryBackend()
    server = ObjectServer(store)

    class LossyAck(RemoteBackend):
        def __init__(self, url):
            super().__init__(url, backoff_base=0.01)
            self.dropped = 0

        def _request(self, method, path, body=None, headers=None):
            r = super()._request(method, path, body=body, headers=headers)
            if method == "POST" and self.dropped == 0 and r.status == 204:
                # the rename happened server-side; the ack evaporates
                # and the client's retry loop re-POSTs, reaching the
                # 404-reconcile branch in put()
                self.dropped += 1
                return super()._request(method, path)
            return r

    rb = LossyAck(server.url)
    try:
        rb.put("k", b"exactly-once")
        assert rb.dropped == 1
        assert store.get("k") == b"exactly-once"
        assert [k for k in store.list() if k.startswith(TEMP_PREFIX)] == []
    finally:
        rb.close()
        server.close()


def test_rename_missing_source_without_committed_dst_fails(tmp_path):
    """404 on a FIRST rename (nothing committed) must surface as a
    failure, not be mistaken for a lost ack."""
    store = MemoryBackend()
    server = ObjectServer(store)

    class EatUpload(RemoteBackend):
        def _request(self, method, path, body=None, headers=None):
            if method == "POST":
                # pretend someone swept our temp key mid-put
                return _Response(404, b"no src", None)
            return super()._request(method, path, body=body,
                                    headers=headers)

    rb = EatUpload(server.url, backoff_base=0.01)
    try:
        with pytest.raises(IOError, match="rename commit lost"):
            rb.put("k", b"x")
        assert not store.exists("k")
    finally:
        rb.close()
        server.close()


# ---------------------------------------------------------------------------
# connection pool sizing
# ---------------------------------------------------------------------------

def test_configure_concurrency_grows_but_never_shrinks(served):
    _server, rb, _store = served
    rb.configure_concurrency(9)
    assert rb._connections == 9
    keys = [f"k{i}" for i in range(30)]
    rb.batch_put([(k, k.encode()) for k in keys])
    assert rb.batch_get(keys) == [k.encode() for k in keys]
    # a smaller hint must not clamp the pool (two ingest workers must
    # not serialize the read fan-out)
    rb.configure_concurrency(2)
    assert rb._connections == 9
    assert rb.batch_get(keys[:5]) == [k.encode() for k in keys[:5]]


def test_vss_sizes_remote_pool_to_ingest_workers(tmp_path):
    from repro.core.store import VSS

    from repro.storage import unwrap

    vss = VSS(str(tmp_path / "vss"), backend="remote", ingest_workers=7)
    try:
        assert unwrap(vss.backend, RemoteBackend) is not None
        assert vss.backend._connections == 7
    finally:
        vss.close()
    vss2 = VSS(str(tmp_path / "vss2"), backend="tiered:remote",
               ingest_workers=5)
    try:
        assert unwrap(vss2.backend.cold, RemoteBackend) is not None
        assert vss2.backend.cold._connections == 5  # forwarded by tiered
    finally:
        vss2.close()


# ---------------------------------------------------------------------------
# VSS crash recovery over the remote layout
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def short_clip():
    from repro.data.video import synthesize_road

    return synthesize_road(30, width=128, height=96, seed=3)


def test_vss_remote_startup_sweeps_temps_and_orphans(tmp_path, short_clip):
    """Crash residue on a remote store: an uncommitted temp upload and
    a published-but-never-indexed object; reopening the store sweeps
    both and committed GOPs stay readable."""
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="remote")
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    rb = vss.backend
    rb._request("PUT", rb._opath(f"{TEMP_PREFIX}crashed-upload"),
                body=b"half")
    rb.put("v/9/0.tvc", b"published-not-indexed")
    vss.catalog.close()  # crash: no clean-shutdown marker
    vss.backend.close()  # the self-hosted server dies with the process

    vss2 = VSS(root, backend="remote")
    try:
        assert vss2.recovery.temps_removed == 1
        assert vss2.recovery.orphans_removed == 1
        assert vss2.recovery.gops_dropped == 0
        out = vss2.read("v", codec="rgb", cache=False).frames
        assert out.shape == short_clip.shape
    finally:
        vss2.close()


def test_reopen_against_wrong_server_refuses(tmp_path, short_clip):
    """The layout identity lives ON the server: pointing an existing
    catalog at a different object server (typo'd URL, wrong migration
    target) must fail the layout guard loudly — a constant fingerprint
    would let startup recovery wipe the catalog AND collect the other
    server's objects as orphans."""
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="remote")
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.catalog.close()  # crash — so the scavenger WOULD run on reopen
    vss.backend.close()

    other = ObjectServer(MemoryBackend())  # a different, healthy store
    try:
        with pytest.raises(ValueError, match="storage layout"):
            VSS(root, backend=f"remote:{other.url}")
        # no object data touched on the wrong server — the probe only
        # minted its (reserved, list-hidden) layout identity
        assert [k for k in other.store.list()
                if not k.startswith("_layout/")] == []
    finally:
        other.close()
    vss2 = VSS(root, backend="remote")  # the right server still opens
    try:
        assert vss2.read("v", codec="rgb", cache=False).frames.shape \
            == short_clip.shape
    finally:
        vss2.close()


def test_error_before_body_read_closes_connection(served):
    """A PUT the server rejects before consuming its body (no
    Content-Length) must close the connection — leaving it open would
    parse the unread body as the next request line and desync every
    later exchange on the socket."""
    import socket as socketlib

    server, _rb, _store = served
    host, port = server.url[len("http://"):].split(":")
    s = socketlib.create_connection((host, int(port)), timeout=5.0)
    try:
        s.sendall(b"PUT /o/k HTTP/1.1\r\nHost: x\r\n"
                  b"Transfer-Encoding: chunked\r\n\r\n"
                  b"5\r\nhello\r\n0\r\n\r\n")
        resp = b""
        while b"\r\n\r\n" not in resp:
            chunk = s.recv(4096)
            if not chunk:
                break
            resp += chunk
        assert b"411" in resp.split(b"\r\n", 1)[0]
        assert b"Connection: close" in resp
        # server closes: recv drains to EOF instead of hanging a
        # desynced keep-alive exchange
        s.settimeout(5.0)
        while True:
            tail = s.recv(4096)
            if not tail:
                break
    finally:
        s.close()


def test_vss_remote_reopens_under_same_layout(tmp_path, short_clip):
    from repro.core.store import VSS

    root = str(tmp_path / "vss")
    vss = VSS(root, backend="remote")
    vss.write("v", short_clip, fps=30.0, codec="tvc-med", gop_frames=10)
    vss.close()
    with pytest.raises(ValueError, match="storage layout"):
        VSS(root, backend="local")
    # remote and tiered:remote share a layout (the hot tier is
    # ephemeral), mirroring tiered:local vs local
    vss2 = VSS(root, backend="tiered:remote")
    try:
        assert np.asarray(
            vss2.read("v", codec="rgb", cache=False).frames
        ).shape == short_clip.shape
    finally:
        vss2.close()


def test_calibration_targets_reach_through_the_cache(tmp_path):
    b = TieredBackend(RemoteBackend.self_hosted(str(tmp_path / "o")),
                      write_back=True)
    try:
        targets = b.calibration_targets()
        assert list(targets) == ["remote"]
        assert isinstance(targets["remote"], RemoteBackend)
    finally:
        b.close()


# ---------------------------------------------------------------------------
# temp-key protocol property test: any interleaving of put/commit/crash
# recovers to indexed-implies-readable
# ---------------------------------------------------------------------------

def _drive(server_store, url, script):
    """Run a put/abandon script against a fresh client, "crash" it
    (drop the client without cleanup), then recover and check the
    invariant: every indexed key reads back exactly, every uncommitted
    upload is swept."""
    rb = RemoteBackend(url, backoff_base=0.01)
    indexed = {}
    for i, (op, slot) in enumerate(script):
        key = f"v/{slot}/0.tvc"
        data = f"gen-{i}".encode() * 8
        if op == "commit":
            rb.put(key, data)     # durable + committed...
            indexed[key] = data   # ...then indexed (publish-then-index)
        else:  # abandon: the crash hits between upload and commit
            rb._request(
                "PUT", rb._opath(f"{TEMP_PREFIX}abandon-{i}"), body=data
            )
    # crash: no flush, no close-protocol — just a new client recovering
    rb2 = RemoteBackend(url, backoff_base=0.01)
    rb2.sweep_temps()
    for key, data in indexed.items():
        assert rb2.get(key) == data, "indexed key must read back exactly"
    assert all(not k.startswith(TEMP_PREFIX) for k in server_store.list())
    rb.close()
    rb2.close()


try:  # property-based when the wheel is present, seeded sweep otherwise
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=15, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from(["commit", "abandon"]),
                  st.integers(0, 3)),
        max_size=12,
    ))
    def test_temp_key_protocol_recovers_indexed_implies_readable(
            script):
        store = MemoryBackend()
        server = ObjectServer(store)
        try:
            _drive(store, server.url, script)
        finally:
            server.close()

except ImportError:  # deterministic sweep fallback (same invariant)
    def test_temp_key_protocol_recovers_indexed_implies_readable():
        import random

        for seed in range(6):
            rng = random.Random(seed)
            script = [
                (rng.choice(["commit", "abandon"]), rng.randrange(4))
                for _ in range(rng.randrange(1, 12))
            ]
            store = MemoryBackend()
            server = ObjectServer(store)
            try:
                _drive(store, server.url, script)
            finally:
                server.close()


# ---------------------------------------------------------------------------
# hedged GETs (tail-latency insurance)
# ---------------------------------------------------------------------------

def test_hedged_get_cuts_tail_latency(tmp_path):
    """Under bimodal injected latency (occasional heavy spikes), a
    hedged client's p99 beats the unhedged client's by a wide margin —
    the duplicate request escapes the spike."""
    import time as _time

    from repro.storage.faults import FaultInjectingBackend

    store = FaultInjectingBackend(
        MemoryBackend(), seed=7, latency=0.002,
        latency_spike=0.12, latency_spike_rate=0.1,
    )
    server = ObjectServer(store)
    plain = RemoteBackend(server.url)
    hedged = RemoteBackend(server.url, hedge_threshold=0.02)
    try:
        plain.put("k", b"x" * 4096)

        def p99(backend, n=60):
            lats = []
            for _ in range(n):
                t0 = _time.perf_counter()
                assert backend.get("k") == b"x" * 4096
                lats.append(_time.perf_counter() - t0)
            lats.sort()
            return lats[max(0, round(0.99 * n) - 1)]

        plain_p99 = p99(plain)
        hedged_p99 = p99(hedged)
        assert hedged.hedges > 0, "spikes never crossed the threshold"
        assert hedged.hedge_wins > 0, "the duplicate never won a race"
        assert hedged_p99 < plain_p99 * 0.8, (
            f"hedging did not cut p99: {hedged_p99:.3f}s vs"
            f" {plain_p99:.3f}s"
        )
    finally:
        plain.close()
        hedged.close()
        server.close()


def test_hedged_get_miss_is_authoritative(served):
    """A 404 is the store speaking, not the network: the hedged path
    short-circuits it instead of waiting out the race."""
    server, _rb, _store = served
    hedged = RemoteBackend(server.url, hedge_threshold=0.01)
    try:
        with pytest.raises(ObjectNotFound):
            hedged.get("never-written")
        hedged.put("real", b"abc")
        assert hedged.get("real") == b"abc"
    finally:
        hedged.close()


def test_hedged_batch_get_does_not_deadlock(served):
    """batch_get fan-out + nested hedge futures must ride separate
    executors; saturating the fan-out pool used to be the deadlock
    shape."""
    server, _rb, _store = served
    hedged = RemoteBackend(server.url, hedge_threshold=0.001,
                           connections=2)
    try:
        items = [(f"k{i}", bytes([i]) * 64) for i in range(24)]
        hedged.batch_put(items)
        got = hedged.batch_get([k for k, _ in items])
        assert got == [v for _, v in items]
    finally:
        hedged.close()


def test_hedge_threshold_validation():
    with pytest.raises(ValueError):
        RemoteBackend("http://127.0.0.1:1", hedge_threshold=0.0)
    with pytest.raises(ValueError):
        RemoteBackend("http://127.0.0.1:1", hedge_threshold=-1.0)


# ---------------------------------------------------------------------------
# untrusted networks: HMAC signed requests + TLS
# ---------------------------------------------------------------------------

_SECRET = b"remote-auth-test-secret"


def test_signed_requests_authenticate_the_wire():
    server = ObjectServer(MemoryBackend(), secret=_SECRET)
    rb = RemoteBackend(server.url, secret=_SECRET, backoff_base=0.01)
    try:
        rb.put("v/1.tvc", b"payload")
        assert rb.get("v/1.tvc") == b"payload"
        assert rb.get_range("v/1.tvc", 0, 4) == b"payl"
        assert rb.stat("v/1.tvc").nbytes == 7
        assert rb.list() == ["v/1.tvc"]
        rb.delete("v/1.tvc")
        assert not rb.exists("v/1.tvc")
    finally:
        rb.close()
        server.close()


def test_unauthenticated_and_tampered_requests_401_without_retry():
    """Missing or wrong signatures are configuration errors: the
    server answers 401, the client raises `RemoteAuthError` on the
    FIRST attempt — hammering a doomed retry loop would only hide the
    misconfiguration."""
    store = MemoryBackend()
    server = ObjectServer(store, secret=_SECRET)
    good = RemoteBackend(server.url, secret=_SECRET, backoff_base=0.01)
    anon = RemoteBackend(server.url, backoff_base=0.01)
    tampered = RemoteBackend(server.url, secret=b"wrong-secret",
                             backoff_base=0.01)
    try:
        good.put("k", b"x")
        rejected0 = server._httpd._c_auth_rejected.value

        with pytest.raises(RemoteAuthError):
            anon.get("k")
        assert anon.retries == 0  # terminal, never transport weather

        with pytest.raises(RemoteAuthError):
            tampered.get("k")
        with pytest.raises(RemoteAuthError):
            tampered.put("k", b"overwrite")
        with pytest.raises(RemoteAuthError):
            tampered.delete("k")
        assert tampered.retries == 0
        assert store.get("k") == b"x"  # nothing mutated
        assert server._httpd._c_auth_rejected.value >= rejected0 + 4
        assert good.get("k") == b"x"  # the honest client is unaffected
    finally:
        for b in (good, anon, tampered):
            b.close()
        server.close()


def test_expired_signature_is_rejected():
    store = MemoryBackend()
    store.put("k", b"x")
    server = ObjectServer(store, secret=_SECRET)
    signer = RequestSigner(_SECRET)
    try:
        stale = signer.headers("GET", "/o/k", now=time.time() - 3600)
        req = urllib.request.Request(server.url + "/o/k", headers=stale)
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 401
        assert ei.value.read() == b"expired"
        # extending the expiry header invalidates the MAC instead
        forged = dict(signer.headers("GET", "/o/k", now=time.time() - 3600))
        forged["X-VSS-Exp"] = str(int(time.time()) + 600)
        req = urllib.request.Request(server.url + "/o/k", headers=forged)
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 401
        assert ei.value.read() == b"bad-signature"
        # a fresh signature over the same request is accepted
        req = urllib.request.Request(
            server.url + "/o/k", headers=signer.headers("GET", "/o/k"))
        assert urllib.request.urlopen(req).read() == b"x"
    finally:
        server.close()


def test_observability_endpoints_stay_open_on_secured_server():
    """/healthz (and /metrics) are the monitoring plane — probes don't
    hold store secrets; the object routes stay locked."""
    server = ObjectServer(MemoryBackend(), secret=_SECRET,
                          health=lambda: {"status": "ok"})
    try:
        with urllib.request.urlopen(server.url + "/healthz") as r:
            assert r.status == 200
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(server.url + "/o/k")
        assert ei.value.code == 401
    finally:
        server.close()


def test_tls_roundtrip_with_pinned_self_signed_cert(tmp_path):
    from test_storage import mint_tls_cert

    cert, key = mint_tls_cert(str(tmp_path / "tls"))
    ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
    ctx.load_cert_chain(cert, key)
    server = ObjectServer(MemoryBackend(), secret=_SECRET, ssl_context=ctx)
    assert server.url.startswith("https://")
    rb = RemoteBackend(server.url, secret=_SECRET, ca_file=cert,
                       backoff_base=0.01)
    try:
        rb.put("v/1.tvc", b"encrypted-in-flight")
        assert rb.get("v/1.tvc") == b"encrypted-in-flight"
        assert rb.get_range("v/1.tvc", 0, 9) == b"encrypted"
        assert rb.list() == ["v/1.tvc"]
    finally:
        rb.close()

    # a client that does NOT pin the cert refuses the connection —
    # default verification rejects the self-signed chain
    strict = RemoteBackend(server.url, secret=_SECRET, max_retries=0)
    try:
        with pytest.raises(RemoteError):
            strict.get("v/1.tvc")
    finally:
        strict.close()
        server.close()


def test_server_list_hides_reserved_namespaces(served):
    """The wire listing must not leak `_rtmp/` upload turds (or other
    reserved namespaces) to clients that do no filtering of their own
    — but an explicit reach-in prefix still answers, because startup
    temp sweeps list `_rtmp/` to clean it."""
    server, rb, store = served
    rb.put("v/1.tvc", b"x")
    store.put("_rtmp/turd", b"t")
    store.put("_journal/seg-0000000000000000.vssj", b"j")
    store.put("_layout/id", b"l")

    def wire_list(prefix=""):
        q = urllib.parse.urlencode({"prefix": prefix})
        with urllib.request.urlopen(server.url + f"/list?{q}") as r:
            return sorted(k for k in r.read().decode().split("\n") if k)

    assert wire_list() == ["v/1.tvc"]
    assert wire_list("v/") == ["v/1.tvc"]
    assert wire_list("_rtmp/") == ["_rtmp/turd"]  # explicit reach-in
    assert rb.sweep_temps() == 1
    assert wire_list("_rtmp/") == []
