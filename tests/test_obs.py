"""Telemetry correctness and cost: `repro.obs` registry math, trace
span trees, exact read-path counter accounting, exposition endpoints,
and the disabled-registry overhead guard."""

import json
import re
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.spec import ReadSpec
from repro.core.store import VSS
from repro.obs import (
    LATENCY_BUCKETS,
    MetricsRegistry,
    Span,
    Tracer,
    instrument_backend,
)
from repro.obs.registry import NULL_COUNTER, NULL_GAUGE, NULL_HISTOGRAM
from repro.storage import FaultInjectingBackend, MemoryBackend, TieredBackend

_LABEL = r'[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"
    rf"(\{{{_LABEL}(,{_LABEL})*\}})?"
    r" (\+Inf|-Inf|NaN|[-+0-9.eE]+)$"
)


# ---------------------------------------------------------------------------
# registry math
# ---------------------------------------------------------------------------

def test_counter_and_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = reg.gauge("t_gauge")
    g.set(7)
    g.inc(2)
    g.dec(4)
    assert g.value == 5.0
    assert reg.value("t_total") == 3.5
    assert reg.value("t_gauge") == 5.0
    assert reg.value("never_registered") == 0.0


def test_histogram_bucket_math():
    """Observations land in the bucket whose edge is the first >= v
    (bisect_left: an exact-edge sample belongs to that edge's bucket),
    overflow goes to +Inf, and sum/count are exact."""
    reg = MetricsRegistry()
    h = reg.histogram("t_h", buckets=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.0, 1.5, 3.0, 8.0, 100.0):
        h.observe(v)
    #            <=1   <=2   <=4   <=8   +Inf
    assert h.counts == [2, 1, 1, 1, 1]
    assert h.count == 6
    assert h.sum == pytest.approx(0.5 + 1.0 + 1.5 + 3.0 + 8.0 + 100.0)
    counts, s, c = reg.histogram_values("t_h")
    assert counts == [2, 1, 1, 1, 1] and c == 6
    assert s == pytest.approx(h.sum)


def test_histogram_percentiles_bucket_bounded():
    """Interpolated quantiles are exact to within one bucket's width
    and clamped by the observed min/max."""
    reg = MetricsRegistry()
    h = reg.histogram("t_p", buckets=LATENCY_BUCKETS)
    samples = [i / 1000.0 for i in range(1, 101)]  # 1ms .. 100ms
    for v in samples:
        h.observe(v)
    p50, p99 = h.percentile(0.5), h.percentile(0.99)
    # true p50 = 50ms sits in the (25ms, 50ms] bucket; p99 = 99ms in
    # the (50ms, 100ms] bucket
    assert 0.025 <= p50 <= 0.0501
    assert 0.05 <= p99 <= 0.1
    assert h.percentile(0.0) >= min(samples) - 1e-12
    assert h.percentile(1.0) <= max(samples) + 1e-12
    empty = reg.histogram("t_p_empty", buckets=(1.0,))
    assert empty.percentile(0.5) == 0.0
    with pytest.raises(ValueError):
        h.percentile(1.5)


def test_concurrent_increments_exact():
    """8 threads x 10k increments on shared handles lose nothing —
    the lock-striped counters and histogram totals are exact."""
    reg = MetricsRegistry()
    c = reg.counter("t_c_total")
    h = reg.histogram("t_c_h", buckets=(0.5,))
    n_threads, n_iter = 8, 10_000

    def work():
        for _ in range(n_iter):
            c.inc()
            h.observe(1.0)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * n_iter
    assert h.count == n_threads * n_iter
    assert h.counts == [0, n_threads * n_iter]


def test_multi_handle_series_sum():
    """Two components registering the same (name, labels) keep exact
    per-instance handles while the series reports their sum — the
    per-instance `stats()` / process-wide /metrics contract."""
    reg = MetricsRegistry()
    a = reg.counter("t_shared_total", labels={"kind": "memory"})
    b = reg.counter("t_shared_total", labels={"kind": "memory"})
    other = reg.counter("t_shared_total", labels={"kind": "remote"})
    a.inc(3)
    b.inc(4)
    other.inc(10)
    assert a.value == 3 and b.value == 4
    assert reg.value("t_shared_total", {"kind": "memory"}) == 7
    assert reg.value("t_shared_total", {"kind": "remote"}) == 10


def test_gauge_fn_weakref_drops_dead_component():
    """Callback gauges on bound methods are weakly held: a collected
    component stops contributing instead of pinning itself alive or
    poisoning the scrape."""
    reg = MetricsRegistry()

    class Component:
        def depth(self):
            return 42.0

    comp = Component()
    reg.gauge_fn("t_depth", comp.depth)
    assert reg.value("t_depth") == 42.0
    del comp
    import gc

    gc.collect()
    assert reg.value("t_depth") == 0.0
    # a raising callback is skipped, not propagated
    reg.gauge_fn("t_bad", lambda: 1 / 0)
    assert reg.value("t_bad") == 0.0
    assert "t_bad" in reg.render_prometheus()


def test_type_and_bucket_conflicts_rejected():
    reg = MetricsRegistry()
    reg.counter("t_conflict")
    with pytest.raises(ValueError):
        reg.gauge("t_conflict")
    reg.histogram("t_hist", buckets=(1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("t_hist", buckets=(1.0, 2.0, 3.0))
    with pytest.raises(ValueError):
        reg.histogram("t_unsorted", buckets=(2.0, 1.0))


def test_prometheus_render_parses():
    """Every rendered sample line matches the text-format grammar;
    histogram buckets are cumulative and end at +Inf; label values are
    escaped."""
    reg = MetricsRegistry()
    reg.counter("t_r_total", "a counter", {"kind": 'we"ird\\path\n'}).inc(2)
    reg.gauge("t_r_gauge", "a gauge").set(1.5)
    h = reg.histogram("t_r_h", "a histogram", buckets=(1.0, 2.0))
    h.observe(0.5)
    h.observe(1.5)
    h.observe(9.0)
    text = reg.render_prometheus()
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert SAMPLE_RE.match(line), f"unparseable: {line!r}"
    assert 't_r_h_bucket{le="1"} 1' in text
    assert 't_r_h_bucket{le="2"} 2' in text
    assert 't_r_h_bucket{le="+Inf"} 3' in text
    assert "t_r_h_count 3" in text
    assert '\\"' in text and "\\\\" in text and "\\n" in text
    # snapshot/json agree with the text form
    snap = reg.snapshot()
    assert snap["t_r_h"]["series"][0]["count"] == 3
    json.loads(reg.render_json())


# ---------------------------------------------------------------------------
# disabled registry: null handles, no wrapper, bounded overhead
# ---------------------------------------------------------------------------

def test_disabled_registry_hands_out_null_handles():
    reg = MetricsRegistry(enabled=False)
    assert reg.counter("x_total") is NULL_COUNTER
    assert reg.gauge("x_g") is NULL_GAUGE
    assert reg.histogram("x_h") is NULL_HISTOGRAM
    reg.gauge_fn("x_fn", lambda: 1.0)  # no-op, nothing registered
    NULL_COUNTER.inc()
    NULL_GAUGE.set(5)
    NULL_HISTOGRAM.observe(1.0)
    assert reg.value("x_total") == 0.0
    assert reg.render_prometheus() == "\n"
    # instrument_backend returns the inner backend itself: zero wrapper
    # frames on the disabled hot path
    mb = MemoryBackend()
    assert instrument_backend(mb, registry=reg) is mb


def test_disabled_registry_overhead_guard():
    """A disabled registry adds <5% to a memory-backend microloop.
    Structurally it adds *nothing* — the instrumented handle IS the
    bare backend — so the timing check pins the contract the structural
    identity implies."""
    import time as _time

    payload = b"x" * 4096
    raw = MemoryBackend()
    instr = instrument_backend(MemoryBackend(),
                               registry=MetricsRegistry(enabled=False))
    assert type(instr) is MemoryBackend

    def microloop(b, n=3000):
        t0 = _time.perf_counter()
        for i in range(n):
            k = f"k{i & 63}"
            b.put(k, payload)
            b.get(k)
        return _time.perf_counter() - t0

    microloop(raw, 200)  # warm both paths
    microloop(instr, 200)
    best_raw = min(microloop(raw) for _ in range(5))
    best_instr = min(microloop(instr) for _ in range(5))
    assert best_instr <= best_raw * 1.05, (
        f"disabled telemetry cost {best_instr / best_raw - 1:.1%}"
        " on the memory microloop (budget: 5%)"
    )


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_ring_and_span_tree():
    tr = Tracer(capacity=3)
    with tr.span("root", spec="v") as root:
        with tr.span("child", parent=root, n=1):
            pass
    got = tr.recent()
    assert len(got) == 1
    assert got[0]["name"] == "root" and got[0]["attrs"] == {"spec": "v"}
    assert got[0]["children"][0]["name"] == "child"
    assert got[0]["dur_s"] >= got[0]["children"][0]["dur_s"] >= 0.0
    for i in range(5):  # ring keeps the newest `capacity` roots
        tr.record(Span(f"r{i}").finish())
    names = [d["name"] for d in tr.recent()]
    assert names == ["r2", "r3", "r4"]
    assert [d["name"] for d in tr.recent(2)] == ["r3", "r4"]
    lines = tr.export_jsonl().splitlines()
    assert [json.loads(ln)["name"] for ln in lines] == names
    tr.clear()
    assert tr.recent() == []
    off = Tracer(enabled=False)
    with off.span("ignored"):
        pass
    assert off.recent() == []


# ---------------------------------------------------------------------------
# layer counters: fault wrapper, tiered cache
# ---------------------------------------------------------------------------

def test_fault_counters_live_on_registry():
    reg = MetricsRegistry()
    b = FaultInjectingBackend(MemoryBackend(), registry=reg)
    b.put("k", b"v")
    assert b.get("k") == b"v"
    b.fail_next(1)
    with pytest.raises(Exception):
        b.get("k")
    assert b.injected_errors == 1  # legacy view ...
    assert reg.value("vss_fault_injected_total", {"fault": "error"}) == 1
    assert b.ops == reg.value("vss_fault_ops_total") == 3


def test_tiered_cache_counters_and_gauges():
    reg = MetricsRegistry()
    cold = MemoryBackend()
    t = TieredBackend(cold, hot_bytes=1 << 20, registry=reg)
    t.put("hot", b"a" * 100)
    t.get("hot")  # served from the hot tier
    cold.put("cold-only", b"b" * 100)  # behind the cache's back
    t.get("cold-only")  # miss -> cold fetch
    assert reg.value("vss_cache_hits_total") == 1
    assert reg.value("vss_cache_misses_total") == 1
    assert reg.value("vss_cache_hot_bytes") > 0
    assert reg.value("vss_cache_hot_objects") >= 1


# ---------------------------------------------------------------------------
# read-path accounting: exact counters, trace trees, cross-layer match
# ---------------------------------------------------------------------------

@pytest.fixture()
def traced_vss(tmp_path, clip):
    reg = MetricsRegistry()
    store = VSS(str(tmp_path / "vss"), backend="memory", registry=reg,
                enable_deferred=False, enable_compaction=False)
    store.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=5)
    yield store, reg
    store.close()


def test_read_batch_exact_counters_and_spans(traced_vss):
    """N specs -> one plan group -> M deduped fetches -> M decodes,
    with every count cross-checked three ways: the VSS planner
    counters, the per-spec trace spans, and the instrumented backend's
    own byte histograms all agree."""
    store, reg = traced_vss
    specs = [
        ReadSpec(name="v", t=(0.0, 1.5), cache=False),
        ReadSpec(name="v", t=(0.5, 2.0), cache=False),
        ReadSpec(name="v", t=(1.0, 2.0), cache=False),
        ReadSpec(name="v", t=(0.0, 1.5), cache=False),  # exact duplicate
    ]
    out = store.read_batch(specs)
    assert len(out) == 4
    st = store.stats("v")
    assert st.specs_read == 4
    assert st.plan_groups == 1          # one (video, view-config) group
    assert st.specs_coalesced == 3      # three rode the first's plan
    # the union of (0,2.0)s at 5-frame GOPs/30fps is 12 objects, each
    # fetched once and decoded once
    assert st.objects_fetched == 12
    assert st.gops_decoded == 12
    assert st.predicted_io_seconds > 0.0
    assert st.actual_io_seconds > 0.0

    roots = store.recent_traces()
    assert len(roots) == 4
    for root in roots:
        assert root["name"] == "read" and root["attrs"]["spec"] == "v"
        assert [c["name"] for c in root["children"]][0] == "plan"
    fetch_spans = [c for r in roots for c in r["children"]
                   if c["name"] == "fetch"]
    decode_spans = [c for r in roots for c in r["children"]
                    if c["name"] == "decode"]
    assert len(decode_spans) == 4
    assert sum(1 for d in decode_spans if d["attrs"].get("shared")) == 1
    assert reg.value("vss_read_duplicate_specs_shared_total") == 1
    # span-level attribution reconciles exactly with the counters
    assert sum(s["attrs"]["objects"] for s in fetch_spans) == 12
    assert sum(s["attrs"]["bytes"] for s in fetch_spans) == st.fetch_bytes
    planned = sum(s["attrs"]["planned"] for s in fetch_spans)
    dedup = sum(s["attrs"]["dedup_hits"] for s in fetch_spans)
    assert planned - dedup == 12
    assert st.gop_fetches_deduped == dedup > 0
    # ... and with the instrumented backend layer: the read path's
    # fetch bytes are exactly what the memory backend served
    counts, nbytes, nobs = reg.histogram_values(
        "vss_backend_op_bytes", {"kind": "memory", "op": "batch_get"})
    assert nobs == 12
    assert int(nbytes) == st.fetch_bytes
    assert reg.value(
        "vss_backend_ops_total", {"kind": "memory", "op": "batch_get"}) == 1


def test_single_read_streams_but_still_counts(traced_vss):
    """The single-spec read() path retains nothing (streaming _BatchIO)
    yet its fetch/decode telemetry and trace root still land."""
    store, reg = traced_vss
    store.read("v", t=(0.0, 0.5), cache=False)
    st = store.stats("v")
    assert st.specs_read == 1
    assert st.objects_fetched == 3      # (0,0.5)s = frames 0..15 -> 3 GOPs
    assert st.gops_decoded == 3
    roots = store.recent_traces()
    assert len(roots) == 1
    names = [c["name"] for c in roots[0]["children"]]
    assert names[0] == "plan" and "decode" in names
    fetch = [c for c in roots[0]["children"] if c["name"] == "fetch"]
    assert fetch and fetch[0]["attrs"]["inline"] is True
    assert fetch[0]["attrs"]["objects"] == 3


def test_trace_ring_is_bounded(tmp_path, clip):
    store = VSS(str(tmp_path / "vss"), backend="memory",
                registry=MetricsRegistry(), trace_capacity=4,
                enable_deferred=False, enable_compaction=False)
    try:
        store.write("v", clip[:20], fps=30.0, codec="tvc-hi", gop_frames=5)
        for _ in range(7):
            store.read("v", t=(0.0, 0.3), cache=False)
        assert len(store.recent_traces()) == 4
    finally:
        store.close()


def test_ingest_stats_view_matches_registry(traced_vss):
    """IngestPipeline.stats() is a thin view over the same registry
    handles /metrics reads — one source of truth."""
    store, reg = traced_vss
    st = store.stats("v").ingest
    assert st is not None and st.gops_published == 12
    assert reg.value("vss_ingest_gops_published_total") == st.gops_published
    assert reg.value("vss_ingest_windows_published_total") == (
        st.windows_published
    )
    assert reg.value("vss_ingest_bytes_published_total") == (
        st.bytes_published
    )
    assert reg.value("vss_ingest_queued_gops") == 0  # drained gauge_fn


def test_stats_is_mapping_compatible(traced_vss):
    store, _ = traced_vss
    st = store.stats("v")
    assert st["gops"] == st.gops == 12
    assert st["physical_videos"] == 1
    assert st["bytes"] > 0 and st["budget"] > 0
    assert set(dict(st)) == {f for f in st}
    with pytest.raises(KeyError):
        st["nope"]


# ---------------------------------------------------------------------------
# exposition: /metrics + /healthz over HTTP, offline dump
# ---------------------------------------------------------------------------

def test_metrics_and_healthz_endpoints(tmp_path, clip):
    store = VSS(str(tmp_path / "vss"), backend="memory",
                registry=MetricsRegistry(),
                enable_deferred=False, enable_compaction=False)
    store.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=5)
    store.read("v", t=(0.0, 0.5), cache=False)
    srv = store.start_metrics_server()
    assert store.start_metrics_server() is srv  # idempotent
    with urllib.request.urlopen(srv.url + "/metrics", timeout=10) as resp:
        assert resp.status == 200
        assert "text/plain" in resp.headers["Content-Type"]
        body = resp.read().decode()
    for line in body.splitlines():
        if line and not line.startswith("#"):
            assert SAMPLE_RE.match(line), f"unparseable: {line!r}"
    for family in ("vss_backend_ops_total", "vss_backend_op_seconds",
                   "vss_read_specs_total", "vss_ingest_gops_published_total"):
        assert f"# TYPE {family}" in body
    with urllib.request.urlopen(srv.url + "/healthz", timeout=10) as resp:
        assert resp.status == 200
        health = json.loads(resp.read())
    assert health["status"] == "ok"
    assert health["backend"]["ok"] is True
    assert health["ingest"]["started"] is True
    assert health["scrub"]["startup_recovery_clean"] is True
    # the metrics-only server has no object plane behind it
    with pytest.raises(urllib.error.HTTPError) as exc_info:
        urllib.request.urlopen(srv.url + "/o/some-key", timeout=10)
    assert exc_info.value.code == 503
    # offline snapshot CLI scrapes the same pair
    from repro.obs import dump

    assert dump.main(["--url", srv.url, "--format", "prom"]) == 0
    store.close()  # closing the store tears the server down
    with pytest.raises((urllib.error.URLError, ConnectionError, OSError)):
        urllib.request.urlopen(srv.url + "/metrics", timeout=2)


def test_healthz_degraded_on_backend_failure(tmp_path):
    store = VSS(str(tmp_path / "vss"), backend="memory",
                registry=MetricsRegistry())
    try:
        def broken(key):
            raise RuntimeError("disk on fire")

        store.backend.exists = broken
        report = store.health()
        assert report["status"] == "degraded"
        assert report["backend"]["ok"] is False
        assert "disk on fire" in report["backend"]["error"]
        srv = store.start_metrics_server()
        with pytest.raises(urllib.error.HTTPError) as exc_info:
            urllib.request.urlopen(srv.url + "/healthz", timeout=10)
        assert exc_info.value.code == 503
        assert json.loads(exc_info.value.read())["status"] == "degraded"
    finally:
        store.close()


def test_traces_empty_and_views_zero_when_disabled(tmp_path):
    """A store on a disabled registry runs the zero-telemetry path:
    no wrapper backend, no spans, registry-backed stats read zero —
    and reads still work."""
    reg = MetricsRegistry(enabled=False)
    store = VSS(str(tmp_path / "vss"), backend="memory", registry=reg,
                enable_deferred=False, enable_compaction=False)
    try:
        assert type(store.backend) is MemoryBackend
        rng = np.random.RandomState(0)
        clip = rng.randint(0, 255, (20, 48, 64, 3), np.uint8)
        store.write("v", clip, fps=30.0, codec="tvc-hi", gop_frames=5)
        out = store.read("v", cache=False)
        assert out.frames.shape == clip.shape
        assert store.recent_traces() == []
        st = store.stats("v")
        assert st.gops == 4             # catalog facts still real
        assert st.specs_read == 0       # registry-backed fields read 0
        assert st.fetch_bytes == 0
    finally:
        store.close()
