"""Fragment selection (§3.1): DP exactness vs brute force and Z3."""
import pytest

from repro.core.select import (
    SegmentChoice,
    SelectionProblem,
    replay_cost,
    solve_brute,
    solve_dp,
    solve_greedy,
    solve_z3,
)

try:  # property-based when the wheel is present, seeded sweep otherwise
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @st.composite
    def problems(draw):
        n_seg = draw(st.integers(1, 5))
        n_vid = draw(st.integers(1, 4))
        choices = []
        for _ in range(n_seg):
            k = draw(st.integers(1, n_vid))
            vids = draw(
                st.lists(st.integers(0, n_vid - 1), min_size=k, max_size=k,
                         unique=True)
            )
            chs = [
                SegmentChoice(
                    v,
                    draw(st.floats(0, 100, allow_nan=False)),
                    draw(st.floats(0, 50, allow_nan=False)),
                )
                for v in vids
            ]
            choices.append(chs)
        segs = [(float(i), float(i + 1)) for i in range(n_seg)]
        return SelectionProblem(segs, choices)

    def _problem_cases(max_examples):
        def deco(fn):
            return settings(max_examples=max_examples, deadline=None)(
                given(problems())(fn)
            )
        return deco

except ImportError:
    import random

    def _make_problem(seed):
        r = random.Random(seed)
        n_seg = r.randint(1, 5)
        n_vid = r.randint(1, 4)
        choices = []
        for _ in range(n_seg):
            vids = r.sample(range(n_vid), r.randint(1, n_vid))
            choices.append([
                SegmentChoice(v, r.uniform(0, 100), r.uniform(0, 50))
                for v in vids
            ])
        segs = [(float(i), float(i + 1)) for i in range(n_seg)]
        return SelectionProblem(segs, choices)

    def _problem_cases(max_examples):
        def deco(fn):
            cases = [_make_problem(s) for s in range(min(max_examples, 60))]
            return pytest.mark.parametrize("p", cases)(fn)
        return deco


@_problem_cases(150)
def test_dp_matches_brute_force(p):
    dp = solve_dp(p)
    brute = solve_brute(p)
    assert abs(dp.cost - brute.cost) < 1e-6
    assert abs(replay_cost(p, dp.assignment) - dp.cost) < 1e-6


@_problem_cases(25)
def test_z3_matches_dp(p):
    pytest.importorskip("z3")
    z = solve_z3(p)
    dp = solve_dp(p)
    assert abs(z.cost - dp.cost) < 1e-5  # same optimum (ties may differ)


@_problem_cases(100)
def test_greedy_never_beats_optimal(p):
    g = solve_greedy(p)
    dp = solve_dp(p)
    assert g.cost >= dp.cost - 1e-9


def test_lookback_waived_on_continuation():
    """Choosing the same video across adjacent segments pays c_l once."""
    chs = [
        [SegmentChoice(0, 10.0, 5.0), SegmentChoice(1, 9.0, 50.0)],
        [SegmentChoice(0, 10.0, 5.0), SegmentChoice(1, 9.0, 50.0)],
    ]
    p = SelectionProblem([(0.0, 1.0), (1.0, 2.0)], chs)
    best = solve_dp(p)
    # video 1 is cheaper per-segment but pays a huge entry cost; staying
    # on video 0 (10+5+10) beats entering video 1 (9+50+9)
    assert [chs[i][a].video_idx for i, a in enumerate(best.assignment)] == [0, 0]
