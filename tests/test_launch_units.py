"""Launch-layer units that do not need the 512-device dry-run env."""
import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES
from repro.launch.specs import batch_specs, cache_specs
from repro.models import model as M
from repro.models.sharding import spec_for


def test_cell_enumeration_counts():
    from repro.launch.dryrun import cells

    all_cells = list(cells())
    assert len(all_cells) == 64  # 32 arch×shape × 2 meshes
    singles = [c for c in all_cells if not c[2]]
    assert len(singles) == 32
    long_cells = {c[0] for c in all_cells if c[1] == "long_500k"}
    assert long_cells == {"recurrentgemma_2b", "xlstm_1_3b"}


def test_batch_specs_per_family():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        b = batch_specs(cfg, SHAPES["train_4k"])
        assert b["tokens"].shape == (256, 4096)
        if cfg.family == "audio":
            assert "frames" in b
        if cfg.family == "vlm":
            assert "patches" in b
        d = batch_specs(cfg, SHAPES["decode_32k"])
        assert d["tokens"].shape == (128, 1)
        assert "frames" not in d and "patches" not in d


def test_cache_specs_shapes():
    cfg = get_config("qwen3_32b")
    cache = cache_specs(cfg, 8, 1024)
    k = cache["groups"]["0_attn"]["k"]
    assert k.shape == (64, 8, 1024, 8, 128)  # (groups, B, L, Hkv, hd)
    c8 = cache_specs(cfg, 8, 1024, kv_int8=True)
    assert c8["groups"]["0_attn"]["k"].dtype == np.int8 or str(
        c8["groups"]["0_attn"]["k"].dtype
    ) == "int8"
    assert "kscale" in c8["groups"]["0_attn"]


def test_param_spec_rules():
    assert spec_for("groups/0_attn/attn/wq", (64, 512, 4, 16), True)[0] is None
    assert spec_for("embed", (1000, 64), False) == ("model", "data")
    assert spec_for("tail_0_attn/mlp/wd", (128, 64), False) == (
        "model", "data",
    )
    assert spec_for("final_norm/scale", (64,), False) == (None,)


def test_abstract_init_matches_real_init():
    from repro.configs import smoke_config

    cfg = smoke_config("recurrentgemma-2b")
    abstract = M.init_model_abstract(cfg)
    real = M.init_model(jax.random.key(0), cfg)
    ta = jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)), abstract)
    tr = jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)), real)
    assert ta == tr


def test_roofline_math():
    from repro.launch.roofline import Roofline

    r = Roofline(
        flops_per_chip=197e12,  # exactly one second of compute
        hbm_bytes_per_chip=819e9 / 2,
        ici_bytes_per_chip=0.0,
        model_flops_total=197e12 * 256 / 2,  # half the compiled flops useful
        chips=256,
    )
    assert r.dominant == "compute"
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.useful_flops_fraction - 0.5) < 1e-9
    assert abs(r.roofline_fraction - 0.5) < 1e-9
