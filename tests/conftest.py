import os
import sys

# tests must see ONE device (the dry-run, and only the dry-run, forces 512)
assert "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
), "tests must run without the dry-run's forced device count"

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture()
def vss(tmp_path):
    from repro.core.store import VSS

    store = VSS(str(tmp_path / "vss"))
    yield store
    store.close()


@pytest.fixture(scope="session")
def clip():
    from repro.data.video import synthesize_road

    return synthesize_road(60, width=128, height=96, seed=0)


@pytest.fixture(scope="session")
def overlap_pair():
    from repro.data.video import synthesize_overlapping_pair

    return synthesize_overlapping_pair(
        12, width=160, height=96, overlap=0.5, seed=1
    )
