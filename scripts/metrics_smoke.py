"""CI metrics smoke: drive a mixed workload through a real store and
validate the observability surface end to end.

    PYTHONPATH=src python scripts/metrics_smoke.py

What it does:

1. builds a ``tiered:remote`` VSS (self-hosted loopback `ObjectServer`
   behind a write-back cache) on a fresh `MetricsRegistry`;
2. runs a mixed workload — pipelined ingest of two streams, single
   reads, a coalescing ``read_batch``, a scrub — and injects one
   transient fault into the object server's backing store so the
   client's retry path actually fires;
3. starts the store's metrics server and scrapes ``GET /metrics`` +
   ``GET /healthz`` over HTTP;
4. asserts every exposed sample line parses as Prometheus text format
   0.0.4, that the expected metric families from every layer are
   present, and that the read-path trace ring is populated.

Exit code 0 on success; raises (non-zero) with a pointed message on
the first violation — this is the CI step that keeps /metrics from
silently rotting.
"""
from __future__ import annotations

import json
import re
import sys
import tempfile
import urllib.request

import numpy as np

SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"          # metric name
    r"(\{([a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")(,[a-zA-Z_][a-zA-Z0-9_]*"
    r"=\"[^\"]*\")*\})?"                    # optional {k="v",...}
    r" (\+Inf|-Inf|NaN|[-+0-9.eE]+)$"      # value
)

# one family per layer the ISSUE requires on /metrics after a mixed
# workload: backend op histograms, cache hit/miss, remote retries,
# ingest queue gauges, planner counters, fault injection, scrub
REQUIRED_FAMILIES = (
    "vss_backend_ops_total",
    "vss_backend_op_seconds",
    "vss_backend_op_bytes",
    "vss_cache_hits_total",
    "vss_cache_misses_total",
    "vss_cache_hot_bytes",
    "vss_remote_retries_total",
    "vss_ingest_gops_published_total",
    "vss_ingest_queued_gops",
    "vss_read_specs_total",
    "vss_read_fetch_bytes_total",
    "vss_read_plan_seconds",
    "vss_plan_predicted_io_seconds_total",
    "vss_fault_injected_total",
    # sub-GOP read path: ranged edge-trim fetches + tiled ROI reads
    "vss_read_ranged_fetches_total",
    "vss_read_ranged_bytes_saved_total",
    "vss_tile_reads_total",
    "vss_tile_fetches_total",
    # workload-adaptive format management: the access profiler observes
    # every read; one adapt() tick exercises the policy counters
    "vss_profiler_records_total",
    "vss_profiler_persists_total",
    "vss_profiler_view_configs",
    "vss_profiler_heat_buckets",
    "vss_adapt_runs_total",
    "vss_adapt_materialize_total",
    "vss_adapt_promote_total",
    "vss_adapt_demote_total",
    "vss_adapt_deferred_steps_total",
    "vss_adapt_resize_total",
    # crash-durable write-back: the journal ticks on every dirty
    # admission of a tiered:remote store (on by default)
    "vss_journal_appends_total",
    "vss_journal_bytes_total",
    "vss_journal_fsyncs_total",
    "vss_journal_segments",
    "vss_journal_pending_objects",
    # signed-request auth: one accepted and one rejected request below
    "vss_remote_auth_accepted_total",
    "vss_remote_auth_rejected_total",
)
# vss_scrub_runs_total / vss_replica_* families are registered by
# ReplicatedBackend only — the backend conformance suite covers them


def main() -> int:
    from repro.core.config import AdaptiveConfig, VSSConfig
    from repro.core.spec import ReadSpec
    from repro.core.store import VSS
    from repro.obs import MetricsRegistry
    from repro.storage import FaultInjectingBackend, RemoteBackend, unwrap

    reg = MetricsRegistry(enabled=True)
    tmp = tempfile.mkdtemp(prefix="vss-metrics-smoke-")
    vss = VSS(tmp, config=VSSConfig(
        backend="tiered:remote", registry=reg,
        adaptive=AdaptiveConfig(enabled=True),
    ))

    # -- mixed workload -------------------------------------------------
    rng = np.random.RandomState(7)
    for name in ("cam0", "cam1"):  # pipelined ingest, two streams
        w = vss.writer(name, fps=30.0, gop_frames=10)
        for _ in range(3):
            w.append(rng.randint(0, 255, (20, 48, 64, 3), np.uint8))
        w.close()
    vss.read("cam0", t=(0.0, 1.0), cache=False)
    # sub-GOP paths: a 3-frame edge trim (ranged fetch) and a tiled
    # ROI read (covering-tile fetch) must tick their counter families
    vss.read("cam0", t=(0.0, 0.1), cache=False)
    from repro.core.spec import WriteSpec
    wt = vss.writer_spec(WriteSpec(name="cam2", fps=30.0, gop_frames=10,
                                   tiles=(2, 2)))
    wt.append(rng.randint(0, 255, (20, 48, 64, 3), np.uint8))
    wt.close()
    vss.read("cam2", t=(0.0, 0.5), roi=(0, 0, 24, 16), cache=False)
    assert reg.value("vss_read_ranged_fetches_total") >= 1, \
        "edge trim did not take the ranged path"
    assert reg.value("vss_tile_fetches_total") >= 1, \
        "tiled ROI read fetched no tile objects"
    vss.read_batch([
        ReadSpec(name="cam0", t=(0.0, 1.5), cache=False),
        ReadSpec(name="cam1", t=(0.5, 2.0), cache=False),
        ReadSpec(name="cam0", t=(0.0, 1.5), cache=False),  # duplicate
    ])

    # -- one injected fault on the wire: wrap the loopback object
    # server's backing store, force one failure, and make a remote
    # round-trip — the client's retry/backoff must absorb it
    remote = unwrap(vss.backend, RemoteBackend)
    assert remote is not None, "tiered:remote must compose a RemoteBackend"
    server = remote._server  # self-hosted loopback instance
    flaky = FaultInjectingBackend(server.store, registry=reg)
    server._httpd.store = flaky
    remote.put("smoke-probe", b"metrics smoke payload")
    flaky.fail_next(1)
    assert remote.get("smoke-probe") == b"metrics smoke payload"
    assert remote.retries >= 1, "injected fault did not exercise a retry"

    # -- durability + auth: the write-back journal must have ticked on
    # ingest (tiered:remote keeps one by default), and a secret-armed
    # server must count one accepted and one rejected request
    from repro.storage import MemoryBackend, ObjectServer, RemoteAuthError

    assert reg.value("vss_journal_appends_total") >= 1, \
        "write-back ingest journaled nothing"
    assert reg.value("vss_journal_fsyncs_total") >= 1, \
        "journal appends paid no fsync barrier"
    secret = b"metrics-smoke-secret"
    auth_server = ObjectServer(MemoryBackend(), secret=secret, registry=reg)
    signed = RemoteBackend(auth_server.url, secret=secret,
                           backoff_base=0.01)
    anon = RemoteBackend(auth_server.url, backoff_base=0.01)
    try:
        signed.put("k", b"authenticated")
        assert signed.get("k") == b"authenticated"
        try:
            anon.get("k")
            raise AssertionError("unauthenticated request was accepted")
        except RemoteAuthError:
            pass
        assert anon.retries == 0, "401 must never be retried"
    finally:
        signed.close()
        anon.close()
        auth_server.close()
    assert reg.value("vss_remote_auth_accepted_total") >= 1
    assert reg.value("vss_remote_auth_rejected_total") >= 1

    # -- adaptive tick: profiler families must have observed the reads
    # above, and one adapt() pass must tick the policy counters
    for _ in range(3):
        vss.read("cam0", t=(0.0, 1.0), resolution=(32, 24), cache=False)
    report = vss.adapt()
    assert reg.value("vss_profiler_records_total") >= 5, \
        "access profiler did not observe the read workload"
    assert reg.value("vss_adapt_runs_total") >= 1, \
        "adapt() tick did not run the policy"
    assert "materialized" in report

    vss.scrub()

    # -- scrape ----------------------------------------------------------
    srv = vss.start_metrics_server()
    with urllib.request.urlopen(srv.url + "/metrics", timeout=10) as resp:
        assert resp.status == 200, f"/metrics answered {resp.status}"
        ctype = resp.headers.get("Content-Type", "")
        assert "text/plain" in ctype, f"unexpected content type {ctype!r}"
        body = resp.read().decode()
    with urllib.request.urlopen(srv.url + "/healthz", timeout=10) as resp:
        assert resp.status == 200, f"/healthz answered {resp.status}"
        health = json.loads(resp.read())
    assert health["status"] == "ok", f"unhealthy store: {health}"
    assert health["backend"]["ok"] and health["ingest"]["started"]

    # -- validate exposition ----------------------------------------------
    samples = 0
    for line in body.splitlines():
        if not line or line.startswith("#"):
            continue
        assert SAMPLE_RE.match(line), f"unparseable sample line: {line!r}"
        samples += 1
    assert samples > 50, f"suspiciously few samples exposed: {samples}"
    families = {
        line.split()[2] for line in body.splitlines()
        if line.startswith("# TYPE")
    }
    missing = [f for f in REQUIRED_FAMILIES if f not in families]
    assert not missing, f"metric families missing from /metrics: {missing}"

    # -- traces ------------------------------------------------------------
    traces = vss.recent_traces()
    assert traces, "read workload left no trace roots"
    spans = {c["name"] for t in traces for c in t.get("children", [])}
    assert {"plan", "decode"} <= spans, f"span tree incomplete: {spans}"

    vss.close()
    print(f"metrics smoke OK: {samples} samples, {len(families)} families,"
          f" {len(traces)} traces, health={health['status']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
