"""Render the §Roofline markdown table from the final sweep JSONLs."""
import json
import sys

BASE = "results/dryrun_v2_baseline.jsonl"
OPT = "results/dryrun_v2_opt.jsonl"


def load(path):
    try:
        return {
            (r["arch"], r["shape"], r["mesh"]): r
            for r in map(json.loads, open(path))
        }
    except FileNotFoundError:
        return {}


def fmt(r):
    rf = r["roofline"]
    return (f"{rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | {rf['dominant'][:4]} | "
            f"{100*rf['roofline_fraction']:.2f}")


def main():
    base = load(BASE)
    opt = load(OPT)
    print("| arch | shape | mesh | GiB/chip | comp_s | mem_s | coll_s |"
          " dom | roof% | opt: mem_s | opt: coll_s | opt roof% |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(base):
        r = base[key]
        rf = r["roofline"]
        o = opt.get(key)
        orf = o["roofline"] if o else None
        print(
            f"| {key[0]} | {key[1]} | {key[2]} |"
            f" {r['resident_bytes_per_chip']/2**30:.2f} |"
            f" {rf['compute_s']:.3f} | {rf['memory_s']:.3f} |"
            f" {rf['collective_s']:.3f} | {rf['dominant'][:4]} |"
            f" {100*rf['roofline_fraction']:.2f} |"
            + (f" {orf['memory_s']:.3f} | {orf['collective_s']:.3f} |"
               f" {100*orf['roofline_fraction']:.2f} |" if orf
               else " - | - | - |")
        )
    # aggregates
    if base:
        dom = {}
        for r in base.values():
            dom[r["roofline"]["dominant"]] = dom.get(
                r["roofline"]["dominant"], 0) + 1
        print(f"\ncells: {len(base)}; dominant-term counts: {dom}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
