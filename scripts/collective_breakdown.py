"""Dev tool: attribute per-chip collective wire bytes + HBM traffic to
source ops (from HLO metadata) for one dry-run cell."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import collections
import re
import sys

import jax

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import *
from repro.launch.steps import *
from repro.models import model as M
from repro.models.sharding import ShardCtx, param_shardings

META_RE = re.compile(r'op_name="([^"]*)"')


def build(arch, shape_name, **ctx_kw):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    ctx = ShardCtx(mesh, **ctx_kw)
    if shape.kind == "train":
        n_micro = max(1, shape.global_batch // ctx.dp_size)
        hyper = TrainHyper(num_microbatches=n_micro)
        state = abstract_train_state(cfg, hyper)
        st_sh = state_shardings(state, mesh)
        batch = batch_specs(cfg, shape)
        b_sh = batch_shardings(batch, mesh)
        step = make_train_step(cfg, ctx, hyper)
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))
        return jitted.lower(state, batch).compile()
    params = M.init_model_abstract(cfg)
    p_sh = param_shardings(params, mesh)
    batch = batch_specs(cfg, shape)
    b_sh = batch_shardings(batch, mesh)
    cache = cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(cache, mesh)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, ctx)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                         out_shardings=(None, c_sh), donate_argnums=(2,))
        return jitted.lower(params, batch, cache).compile()
    step = make_decode_step(cfg, ctx)
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh["tokens"]),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
    return jitted.lower(params, cache, batch["tokens"]).compile()


def breakdown(text, kind="wire"):
    comps = HA.parse_computations(text)
    edges = HA._edges(comps)
    mult, fused = HA._multipliers(comps, edges)
    raw_lines = {}
    for line in text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=", line)
        if m:
            raw_lines[m.group(1)] = line
    by_op = collections.Counter()
    for c in comps.values():
        m = mult[c.name]
        if m == 0:
            continue
        for ins in c.instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            full = raw_lines.get(ins.name, ins.line)
            meta = META_RE.search(full)
            label = meta.group(1)[:90] if meta else "?"
            if kind == "wire" and base in HA.COLLECTIVES:
                n = HA._group_size(full)
                nbytes = HA.shape_bytes(ins.type_str)
                if op.endswith("-start"):
                    nbytes /= 2
                if base == "reduce-scatter":
                    nbytes *= n
                if n > 1:
                    by_op[f"{base} :: {label}"] += m * nbytes * HA._RING[base](n)
            elif kind == "hbm" and not fused[c.name] and (
                op not in HA._SKIP_HBM and base not in HA.COLLECTIVES
                and not op.endswith("-done")
            ):
                out_t = ins.type_str
                out_b = HA.shape_bytes(out_t)
                ots = [c.symbols[o] for o in HA._OPERAND_RE.findall(ins.rest)
                       if o in c.symbols]
                cap = None
                if op in ("dynamic-slice", "gather"):
                    cap = max(out_b, 256)
                elif op == "fusion" and "kind=kInput" not in ins.line:
                    cap = max(4 * out_b, 16384)
                aliased, nbytes = False, 0
                for t in ots:
                    if not aliased and t == out_t:
                        aliased = True
                        continue
                    b = HA.shape_bytes(t)
                    nbytes += min(b, cap) if cap is not None else b
                if not aliased:
                    nbytes += out_b
                by_op[f"{op} :: {label}"] += m * nbytes
    return by_op


if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    kind = sys.argv[3] if len(sys.argv) > 3 else "wire"
    flags = {k: True for k in sys.argv[4:]}
    compiled = build(arch, shape, **flags)
    by = breakdown(compiled.as_text(), kind)
    total = sum(by.values())
    unit = 50e9 if kind == "wire" else 819e9
    print(f"TOTAL {kind}: {total/1e9:.1f} GB/chip = {total/unit:.3f}s")
    for label, b in by.most_common(20):
        print(f"  {b/1e9:9.2f} GB  {100*b/total:5.1f}%  {label}")
