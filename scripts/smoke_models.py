"""Dev harness: per-arch smoke — forward, grad, prefill/decode parity."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, smoke_config
from repro.models import model as M
from repro.models.sharding import ShardCtx


def batch_for(cfg, b, s, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (b, cfg.num_frontend_tokens, cfg.frontend_dim)
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[2], (b, cfg.num_frontend_tokens, cfg.frontend_dim)
        )
    return batch


def main():
    ctx = ShardCtx(None)
    b, s = 2, 24
    only = sys.argv[1:] or ARCH_IDS
    for arch in only:
        cfg = smoke_config(arch)
        if cfg.moe is not None:
            # forward drops tokens at expert capacity (GShard); decode
            # never does — lift capacity so parity isolates real bugs
            import dataclasses
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
            )
        key = jax.random.key(0)
        params = M.init_model(key, cfg)
        batch = batch_for(cfg, b, s, jax.random.key(1))
        logits, aux = jax.jit(
            lambda p, bt: M.forward(p, cfg, bt, ctx)
        )(params, batch)
        assert logits.shape == (b, s, cfg.vocab_size), logits.shape
        assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
        loss, grads = jax.jit(
            jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch, ctx))
        )(params)
        gnorm = jnp.sqrt(sum(
            (g.astype(jnp.float32) ** 2).sum()
            for g in jax.tree_util.tree_leaves(grads)
        ))
        assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
        assert bool(gnorm > 0), f"{arch}: zero grad"

        # prefill/decode parity with the parallel forward
        cache = M.init_cache(cfg, b, max_len=s + 8)
        pre_batch = dict(batch, tokens=batch["tokens"][:, : s - 1])
        lg_pre, cache = jax.jit(
            lambda p, bt, c: M.prefill(p, cfg, bt, c, ctx)
        )(params, pre_batch, cache)
        lg_dec, cache = jax.jit(
            lambda p, c, t: M.decode_step(p, cfg, c, t, ctx)
        )(params, cache, batch["tokens"][:, s - 1 :])
        full = np.asarray(logits, np.float32)
        dec = np.asarray(lg_dec[:, 0], np.float32)
        pre = np.asarray(lg_pre[:, 0], np.float32)
        err_d = np.abs(dec - full[:, -1]).max()
        err_p = np.abs(pre - full[:, -2]).max()
        print(
            f"{arch:28s} loss={float(loss):7.3f} gnorm={float(gnorm):9.3f} "
            f"dec_err={err_d:.3e} pre_err={err_p:.3e}"
        )
        assert err_p < 0.35, f"{arch}: prefill mismatch {err_p}"
        assert err_d < 0.35, f"{arch}: decode mismatch {err_d}"
    print("ALL MODEL SMOKES OK")


if __name__ == "__main__":
    main()
