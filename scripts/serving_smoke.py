"""CI serving smoke: stand up the HTTP serving tier and validate the
whole request surface end to end.

    PYTHONPATH=src python scripts/serving_smoke.py

What it does:

1. builds a fresh `VSS` store with one ingested stream and starts a
   `VSSService` on an ephemeral port (real ThreadingHTTPServer, real
   sockets);
2. fires concurrent mixed-tenant read requests at it — including one
   whose ``deadline_ms`` budget is already spent, which MUST answer
   503 + Retry-After + X-VSS-Shed-Reason while its batchmates answer
   200;
3. fetches every signed segment URL from one manifest, decodes the
   GOPs, and checks the bytes against an in-process read (bit-exact
   wire delivery); rejects a tampered signature;
4. pulls the stored-layout manifest, then writes another video and
   confirms ``/v1/videos`` reflects it;
5. scrapes ``GET /metrics`` + ``GET /healthz``, asserts every sample
   line parses as Prometheus text 0.0.4, and that the serving metric
   families (admission, coalescing, latency, shed) are present with
   sane values.

Exit code 0 on success — the CI step that keeps the serving tier from
silently rotting.
"""
from __future__ import annotations

import json
import re
import sys
import tempfile
import threading
import urllib.error
import urllib.request

import numpy as np

SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"
    r"(\{([a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")(,[a-zA-Z_][a-zA-Z0-9_]*"
    r"=\"[^\"]*\")*\})?"
    r" (\+Inf|-Inf|NaN|[-+0-9.eE]+)$"
)

REQUIRED_FAMILIES = (
    "vss_serve_requests_total",
    "vss_serve_admitted_total",
    "vss_serve_shed_total",
    "vss_serve_batches_total",
    "vss_serve_coalesce_width",
    "vss_serve_queue_wait_seconds",
    "vss_serve_ttfb_seconds",
    "vss_serve_e2e_seconds",
    "vss_serve_queue_depth",
    "vss_serve_inflight_bytes",
    "vss_serve_tenant_tokens",
    "vss_serve_manifest_cache_misses_total",
)


def _post(base, body, tenant):
    req = urllib.request.Request(
        base + "/v1/read", data=json.dumps(body).encode(),
        headers={"X-VSS-Tenant": tenant}, method="POST",
    )
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read() or b"{}"), dict(e.headers)


def main() -> int:
    from repro import codec
    from repro.core.config import VSSConfig
    from repro.core.store import VSS
    from repro.obs import MetricsRegistry
    from repro.serving.config import ServiceConfig
    from repro.serving.service import VSSService

    reg = MetricsRegistry(enabled=True)
    tmp = tempfile.mkdtemp(prefix="vss-serving-smoke-")
    vss = VSS(tmp, config=VSSConfig(registry=reg))
    rng = np.random.RandomState(7)
    clip = rng.randint(0, 255, (60, 48, 64, 3), np.uint8)
    vss.write("cam0", clip, fps=30.0, codec="tvc-med", gop_frames=10)

    service = VSSService(vss, config=ServiceConfig(window_s=0.05),
                         registry=reg)
    base = service.url

    # -- concurrent mixed-tenant burst, one past-deadline ----------------
    n = 6
    outcomes = [None] * n
    barrier = threading.Barrier(n)

    def client(i):
        body = {"name": "cam0", "t": [0.0, 1.0], "codec": "tvc-med"}
        if i == 0:
            body["deadline_ms"] = 0  # expired before dispatch: must shed
        barrier.wait()
        outcomes[i] = _post(base, body, tenant=f"tenant{i % 3}")

    threads = [threading.Thread(target=client, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "serving request hung"
    shed = outcomes[0]
    assert shed[0] == 503, f"past-deadline request answered {shed[0]}"
    assert shed[2]["X-VSS-Shed-Reason"] == "deadline", shed[2]
    assert int(shed[2]["Retry-After"]) >= 1
    for status, _, _ in outcomes[1:]:
        assert status == 200, f"admitted request answered {status}"

    # -- signed-URL data plane: bit-exact bytes, tamper rejected ---------
    manifest = outcomes[1][1]
    segs = []
    for seg in manifest["segments"]:
        with urllib.request.urlopen(base + seg["url"], timeout=30) as r:
            data = r.read()
        assert len(data) == seg["nbytes"]
        segs.append(data)
    got = np.concatenate(
        [codec.decode_gop(codec.deserialize_gop(b)) for b in segs], axis=0
    )
    ref = vss.read("cam0", t=(0.0, 1.0), codec="tvc-med").frames
    assert np.array_equal(got, ref), "wire bytes != in-process read"
    tampered = base + manifest["segments"][0]["url"].replace("sig=", "sig=f")
    try:
        urllib.request.urlopen(tampered, timeout=30)
        raise AssertionError("tampered signature was accepted")
    except urllib.error.HTTPError as e:
        assert e.code == 403, f"tampered signature answered {e.code}"

    # -- stored manifest + catalog views ---------------------------------
    with urllib.request.urlopen(base + "/v1/manifest/cam0", timeout=30) as r:
        layout = json.loads(r.read())
    assert layout["physicals"] and layout["physicals"][0]["gops"]
    vss.write("cam1", clip[:20], fps=30.0, codec="rgb")
    with urllib.request.urlopen(base + "/v1/videos", timeout=30) as r:
        assert json.loads(r.read()) == ["cam0", "cam1"]

    # -- observability ----------------------------------------------------
    with urllib.request.urlopen(base + "/metrics", timeout=30) as r:
        assert r.status == 200
        body = r.read().decode()
    samples = 0
    for line in body.splitlines():
        if not line or line.startswith("#"):
            continue
        assert SAMPLE_RE.match(line), f"unparseable sample line: {line!r}"
        samples += 1
    families = {
        line.split()[2] for line in body.splitlines()
        if line.startswith("# TYPE")
    }
    missing = [f for f in REQUIRED_FAMILIES if f not in families]
    assert not missing, f"serving families missing from /metrics: {missing}"
    assert reg.value("vss_serve_shed_total", {"reason": "deadline"}) >= 1
    assert reg.value("vss_serve_admitted_total") >= n - 1
    with urllib.request.urlopen(base + "/healthz", timeout=30) as r:
        health = json.loads(r.read())
        assert r.status == 200 and health["status"] == "ok", health
    assert health["serving"]["coalescer_alive"] is True

    service.close()
    vss.close()
    print(f"serving smoke OK: {n} concurrent requests ({n - 1} admitted,"
          f" 1 shed), {samples} samples, {len(families)} families,"
          f" health={health['status']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
